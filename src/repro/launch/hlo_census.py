"""Trip-count-aware HLO census — the measurement backbone of §Roofline/§Perf.

``compiled.cost_analysis()`` counts each while-loop *body* once, but a layer
scan executes its body n_layers times; the same under-count hits collective
bytes. This module parses the post-SPMD HLO text and:

  1. builds the computation call graph (while bodies/conditions, fusions,
     calls) and per-computation execution multipliers — a while body's
     multiplier is its caller's multiplier x the loop trip count (estimated
     from the largest leading dim among dynamic-slice/dynamic-update-slice
     operands in the body: scan-stacked inputs are sliced by the induction
     variable; bodies with no such slice count once);
  2. computes per-op dot FLOPs from operand shapes + contracting dims;
  3. sums collective bytes (all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute) by result-buffer size;
  4. sums op output-buffer bytes as an HBM-traffic proxy (fusion outputs
     only — internal fusion ops don't round-trip HBM).

Everything is scaled by the execution multipliers, giving per-device
whole-step totals. Heuristic by design; EXPERIMENTS.md §Roofline documents
the error sources (trip-count inference, gather/elementwise FLOPs ignored).
"""
from __future__ import annotations

import re

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
               "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
               "token": 0}

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _nelem(dim_str: str) -> int:
    n = 1
    for d in _dims(dim_str):
        n *= d
    return n


def _first_shapes(text: str) -> list[tuple[str, str]]:
    return SHAPE_RE.findall(text)


def _buffer_bytes(type_text: str) -> int:
    """Total bytes over all array shapes in a (possibly tuple) type string."""
    total = 0
    for dt, dims in _first_shapes(type_text):
        total += _nelem(dims) * DTYPE_BYTES.get(dt, 4)
    return total


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.shapes: dict[str, str] = {}       # instr name -> type text
        self.dots: list[tuple[str, str, str, str]] = []  # (out, lhs, rhs, attrs)
        self.collectives: list[tuple[str, int]] = []     # (kind, bytes)
        self.out_bytes = 0                      # sum of op result buffers
        self.while_bodies: list[tuple[str, str]] = []    # (body, cond) names
        self.called: list[str] = []             # fusion/call targets
        self.ds_lead = 1                        # max dynamic-slice lead dim
        self.int_consts: list[int] = []         # scalar int constants (bounds)


def parse_hlo(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: "%name (p: t[..]) -> t[..] {" or "ENTRY ..."
        if stripped.endswith("{") and "->" in stripped and "(" in stripped:
            name = stripped.replace("ENTRY", "").strip().split("(")[0].strip()
            name = name.lstrip("%").strip()
            cur = Computation(name)
            comps[name] = cur
            continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        m = INSTR_RE.match(stripped)
        if not m:
            continue
        iname, rhs = m.groups()
        # result type is the prefix up to the op token
        om = re.match(r"^((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)+)\s+"
                      r"([\w\-]+)\(", rhs)
        if not om:
            continue
        type_text, op = om.groups()
        cur.shapes[iname] = type_text
        buf = _buffer_bytes(type_text)
        if op not in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy"):
            cur.out_bytes += buf
        if op == "dot":
            args = re.search(r"dot\(([^)]*)\)", rhs)
            attrs = rhs.split(")", 1)[1] if ")" in rhs else ""
            if args:
                ops_ = [a.strip().lstrip("%") for a in args.group(1).split(",")]
                if len(ops_) >= 2:
                    cur.dots.append((iname, ops_[0], ops_[1], attrs))
        elif any(op.startswith(c) for c in COLLECTIVES):
            kind = next(c for c in COLLECTIVES if op.startswith(c))
            cur.collectives.append((kind, buf))
        elif op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", rhs)
            cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
            if bm:
                cur.while_bodies.append((bm.group(1),
                                         cm.group(1) if cm else ""))
        elif op in ("fusion", "call", "custom-call", "conditional"):
            for t in re.findall(r"(?:calls|to_apply|branch_computations)="
                                r"[{]?%?([\w\.\-{}, %]+)", rhs):
                for nm in re.findall(r"[\w\.\-]+", t):
                    cur.called.append(nm)
        if op == "constant":
            cm2 = re.search(r"constant\((\d+)\)", rhs)
            if cm2:
                cur.int_consts.append(int(cm2.group(1)))
        if op in ("dynamic-slice", "dynamic-update-slice"):
            args = re.search(rf"{op}\(([^)]*)\)", rhs)
            if args:
                first = args.group(1).split(",")[0].strip().lstrip("%")
                # operand shape may be defined earlier in this computation
                src = cur.shapes.get(first)
                if src:
                    sh = _first_shapes(src)
                    if sh:
                        d = _dims(sh[0][1])
                        if d:
                            cur.ds_lead = max(cur.ds_lead, d[0])
    return comps


def dot_flops(comp: Computation) -> float:
    total = 0.0
    for out, lhs, rhs, attrs in comp.dots:
        out_t = comp.shapes.get(out)
        lhs_t = comp.shapes.get(lhs)
        if not out_t or not lhs_t:
            continue
        out_n = _nelem(_first_shapes(out_t)[0][1])
        lhs_dims = _dims(_first_shapes(lhs_t)[0][1])
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
        contract = 1
        if cm:
            for d in _dims(cm.group(1)):
                if d < len(lhs_dims):
                    contract *= lhs_dims[d]
        total += 2.0 * out_n * contract
    return total


def census(hlo_text: str) -> dict:
    comps = parse_hlo(hlo_text)
    # entry = computation that no one calls
    called: set[str] = set()
    fusion_targets: set[str] = set()
    for c in comps.values():
        for b, cond in c.while_bodies:
            called.add(b)
            if cond:
                called.add(cond)
        called.update(c.called)
        fusion_targets.update(c.called)
    entries = [n for n in comps if n not in called] or list(comps)[:1]

    mult: dict[str, float] = {n: 0.0 for n in comps}
    for e in entries:
        mult[e] = 1.0
    # propagate multipliers (call graph is a DAG; fixed-point over few passes)
    for _ in range(len(comps)):
        changed = False
        for name, c in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for b, cond in c.while_bodies:
                # trip count: the loop bound is an integer constant in the
                # while CONDITION computation (scan lowers to i < N); fall
                # back to the body's max dynamic-slice leading dim.
                trips = 1
                if cond in comps and comps[cond].int_consts:
                    trips = max(comps[cond].int_consts)
                elif b in comps:
                    trips = comps[b].ds_lead
                for target, tm in ((b, m * trips), (cond, m * trips)):
                    if target in mult and mult[target] < tm:
                        mult[target] = tm
                        changed = True
            for t in c.called:
                if t in mult and mult[t] < m:
                    mult[t] = m
                    changed = True
        if not changed:
            break

    flops = 0.0
    out_bytes = 0.0
    coll_raw: dict[str, float] = {}
    coll_scaled: dict[str, float] = {}
    n_coll = 0
    for name, c in comps.items():
        m = max(mult.get(name, 0.0), 0.0)
        if m == 0.0:
            m = 1.0          # unreached comps (conservative)
        flops += dot_flops(c) * m
        # fusion-internal ops never round-trip HBM; the fusion op's own
        # output buffer is already counted in its caller.
        if name not in fusion_targets:
            out_bytes += c.out_bytes * m
        for kind, b in c.collectives:
            n_coll += 1
            coll_raw[kind] = coll_raw.get(kind, 0) + b
            coll_scaled[kind] = coll_scaled.get(kind, 0) + b * m
    return {
        "ops": n_coll,
        "bytes_raw": {k: int(v) for k, v in coll_raw.items()},
        "bytes_scaled": {k: int(v) for k, v in coll_scaled.items()},
        "total_scaled": int(sum(coll_scaled.values())),
        "dot_flops_scaled": float(flops),
        "out_bytes_scaled": float(out_bytes),
    }
