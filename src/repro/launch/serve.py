"""Production NKS serving launcher: build/ingest a corpus, start the batched
engine, answer queries from a JSONL request stream (or a built-in demo).

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --d 32 \
        --tier approx --queries 10
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve.engine import NKSEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--u", type=int, default=300)
    ap.add_argument("--t", type=int, default=4)
    ap.add_argument("--corpus", choices=["flickr", "uniform"], default="flickr")
    ap.add_argument("--tier", choices=["exact", "approx", "device"],
                    default="approx")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--queries", type=int, default=10,
                    help="demo random queries (ignored with --requests)")
    ap.add_argument("--requests", default=None,
                    help="JSONL file: {\"keywords\": [..], \"k\": 1}")
    args = ap.parse_args()

    if args.corpus == "flickr":
        ds = flickr_like_dataset(n=args.n, d=args.d, u=args.u, t=args.t, seed=0)
    else:
        ds = synthetic_dataset(n=args.n, d=args.d, u=args.u, t=args.t, seed=0)
    engine = NKSEngine(ds, build_exact=(args.tier == "exact"),
                       build_approx=(args.tier != "exact"))
    print(f"serving: corpus N={ds.n} d={ds.dim} U={ds.n_keywords} "
          f"tier={args.tier}", file=sys.stderr)

    if args.requests:
        reqs = [json.loads(l) for l in open(args.requests) if l.strip()]
        queries = [(r["keywords"], r.get("k", args.k)) for r in reqs]
    else:
        queries = [(q, args.k) for q in
                   random_queries(ds, 3, args.queries, seed=1)]

    for kw, k in queries:
        res = engine.query(kw, k=k, tier=args.tier)
        print(json.dumps({
            "keywords": list(map(int, kw)),
            "latency_ms": round(res.latency_s * 1e3, 2),
            "results": [{"ids": list(c.ids), "diameter": round(c.diameter, 4)}
                        for c in res.candidates],
        }))


if __name__ == "__main__":
    main()
