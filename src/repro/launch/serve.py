"""Production NKS serving launcher: build/ingest a corpus, start the batched
engine, answer queries from a JSONL request stream (or a built-in demo).

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --d 32 \
        --tier approx --queries 10

The request stream is one JSON object per line. ``op`` selects the action
(default ``query``), so a single stream can interleave serving and ingest —
the streaming consistency model (README "Streaming ingest") applies: each
response reflects every earlier op in the stream, never a partial batch.

    {"keywords": [3, 7], "k": 2}                          # query (default op)
    {"keywords": [3, 7], "filter": {"where": [["price", "<", 50]]}}
    {"keywords": [0, 2], "filter": {"tenant": "acme"}}    # tenant-local kws
    {"op": "insert", "points": [[...]], "keywords": [[...]],
     "attrs": {"price": [...]}, "tenant": "acme"}
    {"op": "delete", "ids": [12, 904]}
    {"op": "compact"}

``filter`` applies attribute predicates (grammar: ``[attr, op, value]``
clauses, op in ``< <= > >= == != in between``, conjunction) and tenant
scoping — on a namespaced corpus (``--tenants``) a tenant-scoped query
speaks tenant-local keyword ids. ``--attrs`` attaches synthetic
price/category columns to the demo corpus so filtered requests work out of
the box; inserts must then carry matching ``attrs`` (and ``tenant`` on a
multi-tenant corpus).

Insert responses carry the assigned stable external ids; every ingest
response reports the engine's generation/delta/tombstone state. Compaction
also runs automatically at the ``--compact-ratio`` / ``--compact-min``
cadence.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve.engine import NKSEngine


def _ingest_state(engine: NKSEngine) -> dict:
    return {
        "generation": engine.corpus_generation,
        "delta_points": engine.delta_points,
        "tombstones": engine.tombstone_count,
        "compactions": engine.ingest.compactions,
    }


def handle_request(engine: NKSEngine, req: dict, *, tier: str, k: int) -> dict:
    """Execute one JSONL op against the engine; returns the JSON response."""
    op = req.get("op", "query")
    if op == "query":
        res = engine.query(req["keywords"], k=req.get("k", k), tier=tier,
                           filter=req.get("filter"))
        out = {
            "op": "query",
            "keywords": list(map(int, req["keywords"])),
            "latency_ms": round(res.latency_s * 1e3, 2),
            "results": [{"ids": list(c.ids), "diameter": round(c.diameter, 4)}
                        for c in res.candidates],
        }
        if req.get("filter"):
            out["filter"] = req["filter"]
        return out
    if op == "insert":
        pts = np.asarray(req["points"], dtype=np.float32)
        attrs = {name: np.asarray(col)
                 for name, col in (req.get("attrs") or {}).items()} or None
        tenant = req.get("tenant")
        keywords = req["keywords"]
        ns = getattr(engine.dataset, "tenants", None)
        if tenant is not None and ns is not None:
            # Same convention as tenant-scoped queries: clients speak
            # tenant-LOCAL keyword ids; resolve them into the tenant's global
            # dictionary slots here, so an inserted point is reachable by the
            # very queries its tenant will issue (and can never land in
            # another tenant's namespace). Per-point tenant lists resolve
            # per row.
            if isinstance(tenant, (list, tuple)):
                keywords = [ns.resolve(t, ks)
                            for t, ks in zip(tenant, keywords)]
            else:
                keywords = [ns.resolve(tenant, ks) for ks in keywords]
        ids = engine.insert(pts, keywords, attrs=attrs, tenant=tenant)
        return {"op": "insert", "ids": [int(i) for i in ids],
                **_ingest_state(engine)}
    if op == "delete":
        n = engine.delete(req["ids"])
        return {"op": "delete", "deleted": n, **_ingest_state(engine)}
    if op == "compact":
        ran = engine.compact()
        return {"op": "compact", "compacted": ran, **_ingest_state(engine)}
    raise ValueError(f"unknown op: {op!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--u", type=int, default=300)
    ap.add_argument("--t", type=int, default=4)
    ap.add_argument("--corpus", choices=["flickr", "uniform"], default="flickr")
    ap.add_argument("--tier", choices=["exact", "approx", "device"],
                    default="approx")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--queries", type=int, default=10,
                    help="demo random queries (ignored with --requests)")
    ap.add_argument("--requests", default=None,
                    help="JSONL file: {\"op\": ..., \"keywords\": [..], ...}")
    ap.add_argument("--compact-ratio", type=float, default=0.25,
                    help="auto-compact once delta+tombstones exceed this "
                         "fraction of the bulk corpus")
    ap.add_argument("--compact-min", type=int, default=4096,
                    help="minimum churn before auto-compaction triggers")
    ap.add_argument("--attrs", action="store_true",
                    help="attach synthetic price/category attribute columns "
                         "(enables filtered requests)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="build a multi-tenant corpus with this many tenants "
                         "(t0, t1, ...), each with a private keyword "
                         "namespace of size --u; implies --attrs")
    args = ap.parse_args()

    if args.tenants:
        from repro.data.synthetic import synthetic_tenants
        per = max(args.n // args.tenants, 1)
        ds = synthetic_tenants({f"t{i}": per for i in range(args.tenants)},
                               d=args.d, u=args.u, t=args.t, seed=0)
    elif args.corpus == "flickr":
        ds = flickr_like_dataset(n=args.n, d=args.d, u=args.u, t=args.t, seed=0)
    else:
        ds = synthetic_dataset(n=args.n, d=args.d, u=args.u, t=args.t, seed=0)
    if args.attrs and not args.tenants:
        from repro.data.synthetic import attach_attrs
        ds = attach_attrs(ds, seed=0)
    engine = NKSEngine(ds, build_exact=(args.tier == "exact"),
                       build_approx=(args.tier != "exact"),
                       compact_ratio=args.compact_ratio,
                       compact_min=args.compact_min)
    print(f"serving: corpus N={ds.n} d={ds.dim} U={ds.n_keywords} "
          f"tier={args.tier}", file=sys.stderr)

    if args.requests:
        reqs = [json.loads(line) for line in open(args.requests) if line.strip()]
    else:
        reqs = [{"keywords": q, "k": args.k} for q in
                random_queries(ds, 3, args.queries, seed=1)]

    for req in reqs:
        print(json.dumps(handle_request(engine, req, tier=args.tier, k=args.k)))


if __name__ == "__main__":
    main()
