"""Production NKS serving launcher: build/ingest a corpus, start the batched
engine, answer queries from a JSONL request stream (or a built-in demo).

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --d 32 \
        --tier approx --queries 10

The request stream is one JSON object per line. ``op`` selects the action
(default ``query``), so a single stream can interleave serving and ingest —
the streaming consistency model (README "Streaming ingest") applies: each
response reflects every earlier op in the stream, never a partial batch.

    {"keywords": [3, 7], "k": 2}                          # query (default op)
    {"keywords": ["3", "7^4"], "m": 1, "score": true}     # flexible semantics
    {"keywords": [3, 7], "filter": {"where": [["price", "<", 50]]}}
    {"keywords": [0, 2], "filter": {"tenant": "acme"}}    # tenant-local kws
    {"op": "insert", "points": [[...]], "keywords": [[...]],
     "attrs": {"price": [...]}, "tenant": "acme"}
    {"op": "delete", "ids": [12, 904]}
    {"op": "compact"}
    {"op": "health"}                                      # runtime/engine state
    {"op": "snapshot"}                                    # requires --wal

A malformed line or failing op never kills the stream: each bad request gets
a structured ``{"op": ..., "error": ..., "status": "error"}`` response and
serving continues.

Flexible query semantics (README "Query semantics") ride on the query op:
a ``keywords`` entry may be a ``"<id>^<weight>"`` boost string (merged with
an explicit ``weights`` object — the inline boost wins on conflict), ``m``
asks for m-of-k partial coverage, and ``score``/``alpha`` switch ranking to
the blended coverage/cost score — scored result rows gain a ``score`` field.

``filter`` applies attribute predicates (grammar: ``[attr, op, value]``
clauses, op in ``< <= > >= == != in between``, conjunction) and tenant
scoping — on a namespaced corpus (``--tenants``) a tenant-scoped query
speaks tenant-local keyword ids. ``--attrs`` attaches synthetic
price/category columns to the demo corpus so filtered requests work out of
the box; inserts must then carry matching ``attrs`` (and ``tenant`` on a
multi-tenant corpus).

``--runtime`` routes requests through the fault-tolerant async runtime
(``serve.runtime``): consecutive queries are admitted together and coalesced
into batched dispatches; ingest ops are awaited before later requests are
admitted, preserving the stream contract. Responses gain ``degraded: true``
when overload shed an exact request to the approx tier. ``--wal DIR``
attaches the crash-recovery write-ahead log — every ingest ack is then
durable (README "Serving runtime").

Insert responses carry the assigned stable external ids; every ingest
response reports the engine's generation/delta/tombstone state. Compaction
also runs automatically at the ``--compact-ratio`` / ``--compact-min``
cadence (off-thread under ``--runtime``).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core.semantics import parse_weighted_keywords
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve.engine import NKSEngine


def _ingest_state(engine: NKSEngine) -> dict:
    return {
        "generation": engine.corpus_generation,
        "delta_points": engine.delta_points,
        "tombstones": engine.tombstone_count,
        "compactions": engine.ingest.compactions,
    }


def _resolve_insert_keywords(engine: NKSEngine, req: dict) -> list:
    """Tenant-LOCAL keyword ids -> global dictionary slots (same convention
    as tenant-scoped queries), so an inserted point is reachable by the very
    queries its tenant will issue and can never land in another tenant's
    namespace. Per-point tenant lists resolve per row."""
    keywords = req["keywords"]
    tenant = req.get("tenant")
    ns = getattr(engine.dataset, "tenants", None)
    if tenant is None or ns is None:
        return keywords
    if isinstance(tenant, (list, tuple)):
        return [ns.resolve(t, ks) for t, ks in zip(tenant, keywords)]
    return [ns.resolve(tenant, ks) for ks in keywords]


def _parse_query_semantics(req: dict) -> tuple[list[int], dict | None]:
    """Keyword ids plus the request's semantics wire-dict (or None for a
    classic request). ``keywords`` entries may use the ``"7^4"`` boost
    grammar; inline boosts merge over an explicit ``weights`` object and win
    on conflict. Validation happens in ``QuerySemantics.coerce`` downstream."""
    kws, boosts = parse_weighted_keywords(req["keywords"])
    weights = {int(kw): float(w)
               for kw, w in (req.get("weights") or {}).items()}
    weights.update(boosts)
    sem: dict = {}
    if req.get("m") is not None:
        sem["m"] = int(req["m"])
    if weights:
        sem["weights"] = weights
    if req.get("score"):
        sem["score"] = True
    if req.get("alpha") is not None:
        sem["alpha"] = float(req["alpha"])
    return kws, (sem or None)


def _result_row(c) -> dict:
    row = {"ids": list(c.ids), "diameter": round(c.diameter, 4)}
    if c.score is not None:
        row["score"] = round(c.score, 6)
    return row


def handle_request(engine: NKSEngine, req: dict, *, tier: str, k: int) -> dict:
    """Execute one JSONL op against the engine; returns the JSON response.

    Raises on a bad request — the serving loop wraps this in
    :func:`handle_request_safe` to produce error envelopes instead."""
    op = req.get("op", "query")
    if op == "query":
        kws, sem = _parse_query_semantics(req)
        res = engine.query(kws, k=req.get("k", k),
                           tier=req.get("tier", tier),
                           filter=req.get("filter"), semantics=sem)
        out = {
            "op": "query",
            "keywords": kws,
            "latency_ms": round(res.latency_s * 1e3, 2),
            "results": [_result_row(c) for c in res.candidates],
        }
        if req.get("filter"):
            out["filter"] = req["filter"]
        return out
    if op == "insert":
        pts = np.asarray(req["points"], dtype=np.float32)
        attrs = {name: np.asarray(col)
                 for name, col in (req.get("attrs") or {}).items()} or None
        keywords = _resolve_insert_keywords(engine, req)
        ids = engine.insert(pts, keywords, attrs=attrs,
                            tenant=req.get("tenant"))
        return {"op": "insert", "ids": [int(i) for i in ids],
                **_ingest_state(engine)}
    if op == "delete":
        n = engine.delete(req["ids"])
        return {"op": "delete", "deleted": n, **_ingest_state(engine)}
    if op == "compact":
        ran = engine.compact()
        return {"op": "compact", "compacted": ran, **_ingest_state(engine)}
    if op == "snapshot":
        return {"op": "snapshot", "snapshot": engine.snapshot(),
                **_ingest_state(engine)}
    if op == "health":
        # Synchronous loop: no queue, never degraded.
        return {"op": "health", "queue_depth": 0, "degraded": False,
                "runtime": False,
                "wal_attached": engine.wal_stats is not None,
                **_ingest_state(engine)}
    raise ValueError(f"unknown op: {op!r}")


def handle_request_safe(engine: NKSEngine, req, *, tier: str, k: int) -> dict:
    """One request in, one response out — errors become structured envelopes
    so a malformed request can never kill the stream."""
    if isinstance(req, dict) and "__parse_error__" in req:
        return {"op": "parse", "status": "error",
                "error": req["__parse_error__"]}
    if not isinstance(req, dict):
        return {"op": "parse", "status": "error",
                "error": f"request must be a JSON object, got "
                         f"{type(req).__name__}"}
    try:
        return handle_request(engine, req, tier=tier, k=k)
    except Exception as e:
        return {"op": str(req.get("op", "query")), "status": "error",
                "error": f"{type(e).__name__}: {e}"}


# ---------------------------------------------------------------- runtime path
def _to_runtime_request(engine: NKSEngine, req: dict, *, tier: str,
                        k: int) -> dict:
    """Validate/convert a JSONL request into the runtime's structured form
    (raises on a malformed request — caller wraps)."""
    op = req.get("op", "query")
    if op == "query":
        kws, sem = _parse_query_semantics(req)
        return {"op": "query", "keywords": kws,
                "k": int(req.get("k", k)), "tier": req.get("tier", tier),
                "filter": req.get("filter"), "semantics": sem}
    if op == "insert":
        attrs = {name: np.asarray(col)
                 for name, col in (req.get("attrs") or {}).items()} or None
        return {"op": "insert",
                "points": np.asarray(req["points"], dtype=np.float32),
                "keywords": _resolve_insert_keywords(engine, req),
                "attrs": attrs, "tenant": req.get("tenant")}
    if op == "delete":
        return {"op": "delete", "ids": req["ids"]}
    if op in ("compact", "snapshot", "health"):
        return {"op": op}
    raise ValueError(f"unknown op: {op!r}")


def _format_runtime_response(req: dict, resp) -> dict:
    if resp.status != "ok":
        return {"op": resp.op, "status": resp.status, "error": resp.error}
    if resp.op == "query":
        out = {
            "op": "query",
            "keywords": parse_weighted_keywords(req["keywords"])[0],
            "latency_ms": round(resp.latency_s * 1e3, 2),
            "results": [_result_row(c) for c in resp.payload["candidates"]],
        }
        if resp.degraded:
            out["degraded"] = True
            out["tier"] = resp.tier
        if req.get("filter"):
            out["filter"] = req["filter"]
        return out
    return {"op": resp.op, **resp.payload}


def serve_with_runtime(runtime, engine: NKSEngine, reqs, *, tier: str, k: int):
    """Drive the async runtime while preserving the JSONL stream contract:
    runs of consecutive queries are admitted together (so they coalesce into
    batched dispatches); an ingest op is awaited before anything later is
    admitted (its ack orders the stream). Yields one response dict per
    request, in request order."""
    def flush(window):
        for raw, ticket in window:
            if ticket is None:        # conversion failed; raw is the envelope
                yield raw
            else:
                yield _format_runtime_response(raw, ticket.result())
    window: list = []
    for req in reqs:
        envelope = None
        rt_req = None
        if isinstance(req, dict) and "__parse_error__" in req:
            envelope = {"op": "parse", "status": "error",
                        "error": req["__parse_error__"]}
        elif not isinstance(req, dict):
            envelope = {"op": "parse", "status": "error",
                        "error": f"request must be a JSON object, got "
                                 f"{type(req).__name__}"}
        else:
            try:
                rt_req = _to_runtime_request(engine, req, tier=tier, k=k)
            except Exception as e:
                envelope = {"op": str(req.get("op", "query")),
                            "status": "error",
                            "error": f"{type(e).__name__}: {e}"}
        if envelope is not None:
            window.append((envelope, None))
            continue
        if rt_req["op"] == "query":
            window.append((req, runtime.submit(rt_req)))
            continue
        # Ingest/health: drain queries first, then await the op's ack before
        # admitting anything later.
        yield from flush(window)
        window = []
        yield _format_runtime_response(req, runtime.submit(rt_req).result())
    yield from flush(window)


def _run_ingest_pipeline(target, ds, args) -> dict:
    """Drive ``--ingest-docs`` raw documents through the job-queue pipeline
    into ``target`` (engine, or runtime under ``--runtime``). Documents are
    ``flickr_like`` payloads matched to the serving corpus: same point dim,
    same (per-tenant) dictionary size, attrs iff the corpus has them."""
    import os
    import tempfile

    from repro.data.ingest import (IngestPipeline, JobStore,
                                   ProjectionEmbedder, flickr_like_documents)
    tenanted = ds.tenants is not None
    u = args.u if tenanted else ds.n_keywords
    d_raw = 4 * ds.dim
    docs, vocab = flickr_like_documents(
        args.ingest_docs, d_raw=d_raw, u=u, t=args.t, seed=11,
        tenants=list(ds.tenants.names) if tenanted else None,
        with_attrs=bool(ds.attrs))
    embedder = ProjectionEmbedder(ds.dim, vocab, d_raw=d_raw, seed=11)
    root = args.ingest_jobs or tempfile.mkdtemp(prefix="nks-ingest-")
    os.makedirs(root, exist_ok=True)
    store = JobStore(os.path.join(root, "jobs.jsonl"))
    pipe = IngestPipeline(store, target, embedder,
                          workers=args.ingest_workers)
    outcome = pipe.recover()          # resolve a prior run's open intent
    if outcome:
        print(f"ingest: recovered open intent -> {outcome}", file=sys.stderr)
    store.add(docs)
    report = pipe.run(timeout_s=max(120.0, args.ingest_docs / 50.0))
    store.close()
    print(f"ingest: {report['docs_done']} docs in {report['wall_s']:.2f}s "
          f"({report['docs_per_s']:.0f} docs/s, "
          f"retries={report['retries']} reclaims={report['reclaims']} "
          f"failed={report['docs_failed']}) jobs={root}", file=sys.stderr)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--u", type=int, default=300)
    ap.add_argument("--t", type=int, default=4)
    ap.add_argument("--corpus", choices=["flickr", "uniform"], default="flickr")
    ap.add_argument("--tier", choices=["exact", "approx", "device"],
                    default="approx")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--queries", type=int, default=10,
                    help="demo random queries (ignored with --requests)")
    ap.add_argument("--requests", default=None,
                    help="JSONL file: {\"op\": ..., \"keywords\": [..], ...}")
    ap.add_argument("--compact-ratio", type=float, default=0.25,
                    help="auto-compact once delta+tombstones exceed this "
                         "fraction of the bulk corpus")
    ap.add_argument("--compact-min", type=int, default=4096,
                    help="minimum churn before auto-compaction triggers")
    ap.add_argument("--attrs", action="store_true",
                    help="attach synthetic price/category attribute columns "
                         "(enables filtered requests)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="build a multi-tenant corpus with this many tenants "
                         "(t0, t1, ...), each with a private keyword "
                         "namespace of size --u; implies --attrs")
    ap.add_argument("--runtime", action="store_true",
                    help="serve through the async fault-tolerant runtime "
                         "(admission queue, coalesced batches, off-thread "
                         "compaction)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="runtime admission-queue bound (backpressure past "
                         "it)")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="runtime coalesced query batch cap")
    ap.add_argument("--batch-window-ms", type=float, default=2.0,
                    help="runtime coalescing window for a young batch head")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request deadline (expired requests get "
                         "a timeout response)")
    ap.add_argument("--wal", default=None, metavar="DIR",
                    help="attach a write-ahead log rooted here: every ingest "
                         "ack becomes durable; recover with "
                         "NKSEngine.recover(DIR)")
    ap.add_argument("--ingest-docs", type=int, default=0,
                    help="before serving, run this many flickr_like raw "
                         "documents through the ingestion job pipeline "
                         "(data/ingest.py) into the engine — through the "
                         "admission queue under --runtime, so pipeline "
                         "batches coalesce with other ingest")
    ap.add_argument("--ingest-workers", type=int, default=2,
                    help="ingestion pipeline worker threads")
    ap.add_argument("--ingest-jobs", default=None, metavar="DIR",
                    help="persist the ingestion job journal here (reopening "
                         "resumes unfinished jobs); default: a temp dir")
    args = ap.parse_args()

    if args.tenants:
        from repro.data.synthetic import synthetic_tenants
        per = max(args.n // args.tenants, 1)
        ds = synthetic_tenants({f"t{i}": per for i in range(args.tenants)},
                               d=args.d, u=args.u, t=args.t, seed=0)
    elif args.corpus == "flickr":
        ds = flickr_like_dataset(n=args.n, d=args.d, u=args.u, t=args.t, seed=0)
    else:
        ds = synthetic_dataset(n=args.n, d=args.d, u=args.u, t=args.t, seed=0)
    if args.attrs and not args.tenants:
        from repro.data.synthetic import attach_attrs
        ds = attach_attrs(ds, seed=0)
    engine = NKSEngine(ds, build_exact=(args.tier == "exact"),
                       build_approx=(args.tier != "exact"),
                       compact_ratio=args.compact_ratio,
                       compact_min=args.compact_min)
    if args.wal:
        engine.attach_wal(args.wal)
    print(f"serving: corpus N={ds.n} d={ds.dim} U={ds.n_keywords} "
          f"tier={args.tier}"
          + (f" wal={args.wal}" if args.wal else "")
          + (" runtime=async" if args.runtime else ""), file=sys.stderr)

    if args.requests:
        reqs = []
        for line in open(args.requests):
            if not line.strip():
                continue
            try:
                reqs.append(json.loads(line))
            except json.JSONDecodeError as e:
                reqs.append({"__parse_error__": f"malformed JSON: {e}"})
    else:
        reqs = [{"keywords": q, "k": args.k} for q in
                random_queries(ds, 3, args.queries, seed=1)]

    if args.runtime:
        from repro.serve.runtime import RuntimeConfig, ServingRuntime
        runtime = ServingRuntime(engine, RuntimeConfig(
            max_queue=args.max_queue, max_batch=args.max_batch,
            batch_window_s=args.batch_window_ms / 1e3,
            default_deadline_s=args.deadline_s,
            tier=args.tier, k=args.k))
        try:
            if args.ingest_docs:
                _run_ingest_pipeline(runtime, ds, args)
            for out in serve_with_runtime(runtime, engine, reqs,
                                          tier=args.tier, k=args.k):
                print(json.dumps(out), flush=True)
        finally:
            runtime.close()
    else:
        if args.ingest_docs:
            _run_ingest_pipeline(engine, ds, args)
        for req in reqs:
            print(json.dumps(handle_request_safe(engine, req, tier=args.tier,
                                                 k=args.k)), flush=True)


if __name__ == "__main__":
    main()
