"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 1000 --ckpt-dir /ckpt/minicpm [--multi-pod]

On a real TPU fleet each host runs this same entry point
(jax.distributed.initialize picks up the pod topology); offline it runs the
smoke-reduced config on the local device so the full path — sharded params,
fault-tolerant loop, checkpoint/resume — is exercisable anywhere.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import TRAIN_4K
from repro.data.token_pipeline import PipelineConfig, TokenPipeline
from repro.launch import shardings as sh
from repro.launch.mesh import dp_axes, dp_size, make_production_mesh
from repro.launch.step import make_train_step
from repro.models.api import model_api
from repro.models.hints import enable_hints_mesh
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_loop import LoopConfig, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local 1x1 mesh (CPU)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        gb, sl = args.global_batch or 4, args.seq_len or 32
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        gb, sl = args.global_batch or TRAIN_4K.global_batch, \
            args.seq_len or TRAIN_4K.seq_len
    enable_hints_mesh(mesh, dp_axes(mesh), "model")

    api = model_api(cfg)
    opt_cfg = OptimizerConfig(total_steps=args.steps,
                              schedule=cfg.schedule,
                              state_dtype="bfloat16" if not args.smoke
                              else "float32")
    step_fn = make_train_step(cfg, opt_cfg)

    params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    pspecs = sh.param_specs(params_struct, mesh)
    ospecs = sh.opt_specs(params_struct, mesh)

    with mesh:
        jit_init = jax.jit(
            lambda k: (api.init(k), ),
            out_shardings=(sh.named(pspecs, mesh),))
        jit_step = jax.jit(
            step_fn,
            in_shardings=(sh.named(pspecs, mesh), sh.named(ospecs, mesh), None),
            out_shardings=(sh.named(pspecs, mesh), sh.named(ospecs, mesh), None),
            donate_argnums=(0, 1))

        def init_state():
            (params,) = jit_init(jax.random.PRNGKey(0))
            return {"params": params,
                    "opt": init_opt_state(params, opt_cfg)}

        def stepper(state, batch):
            batch = jax.tree.map(jnp.asarray, batch)
            params, opt, metrics = jit_step(state["params"], state["opt"], batch)
            return {"params": params, "opt": opt}, metrics

        pipe = TokenPipeline(PipelineConfig(
            vocab_size=cfg.vocab_size, global_batch=gb, seq_len=sl))
        loop = TrainLoop(LoopConfig(total_steps=args.steps,
                                    ckpt_dir=args.ckpt_dir,
                                    ckpt_every=args.ckpt_every),
                         stepper, pipe, init_state)
        state, hist = loop.run(dp_rank=0, dp_size=1 if args.smoke
                               else dp_size(mesh))
    if hist:
        print(f"{cfg.name}: {len(hist)} steps, "
              f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}, "
              f"stragglers={hist[-1]['stragglers']}")


if __name__ == "__main__":
    main()
