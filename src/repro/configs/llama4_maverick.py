"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (per expert), vocab=202048, MoE 128 experts top-1 with shared
expert, MoE every 2nd layer (interleaved dense d_ff=4*8192/2) — yields
~400B total / ~17B active. [hf:meta-llama/Llama-4-*]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16_384,            # dense (non-MoE) interleaved layers
    vocab_size=202_048,
    head_dim=128,
    qk_norm=True,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, every=2,
                  shared_expert=True, aux_loss_weight=0.001),
)
