"""Architecture + run configuration system.

One :class:`ArchConfig` per assigned architecture (exact numbers from the
assignment table), plus a ``smoke()`` reduction used by CPU tests. Input-shape
cells (train_4k / prefill_32k / decode_32k / long_500k) are :class:`ShapeCell`
constants shared by every arch.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "ssm", "moe", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int          # per-expert hidden width
    every: int = 1            # MoE layer every `every` layers (others dense)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    def expert_parallel(self, d_model: int) -> bool:
        """EP regime (experts pinned to the TP axis, tokens move) iff the
        per-layer expert weights are heavy (>2 GB bf16); light-expert MoEs
        replicate experts over TP and keep tokens local (EXPERIMENTS.md
        §Perf iterations 2/5 measured the crossover)."""
        return 3 * self.n_experts * self.d_ff_expert * d_model * 2 > 2e9


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # None -> d_model // n_heads
    qk_norm: bool = False
    attn_bias: bool = False              # qwen1.5-style qkv bias
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    schedule: Literal["wsd", "cosine"] = "cosine"
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (hymba): parallel attention + SSM heads in each layer
    hybrid: bool = False
    # vlm: every `cross_attn_every`-th layer is a vision cross-attention layer
    cross_attn_every: int = 0
    vision_tokens: int = 0
    vision_dim: int = 0
    # audio (whisper): encoder-decoder; n_layers == decoder layers
    enc_layers: int = 0
    audio_frames: int = 0                # stub conv frontend output length
    # which shape cells are supported (skips recorded in DESIGN/EXPERIMENTS)
    sub_quadratic: bool = False          # can run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.enc_layers > 0

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        small_moe = None
        if self.moe is not None:
            small_moe = dataclasses.replace(
                self.moe, n_experts=min(8, self.moe.n_experts),
                top_k=min(2, self.moe.top_k), d_ff_expert=64)
        small_ssm = None
        if self.ssm is not None:
            small_ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=8,
                                            chunk=8, n_groups=1)
        heads = min(4, self.n_heads)
        kv = max(1, min(heads, self.n_kv_heads * heads // self.n_heads or 1))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, min(4, self.n_layers)) if self.cross_attn_every == 0
            else 2 * max(2, self.cross_attn_every // 2),
            d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
            d_ff=128, vocab_size=256, moe=small_moe, ssm=small_ssm,
            cross_attn_every=min(self.cross_attn_every, 2) if self.cross_attn_every else 0,
            vision_tokens=min(self.vision_tokens, 8), vision_dim=32 if self.vision_dim else 0,
            enc_layers=min(self.enc_layers, 2), audio_frames=min(self.audio_frames, 16),
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def is_serving(self) -> bool:
        return self.kind != "train"


TRAIN_4K = ShapeCell("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524_288, 1, "decode")
ALL_CELLS = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def supported_cells(cfg: ArchConfig) -> list[ShapeCell]:
    """long_500k requires sub-quadratic sequence mixing (SSM/hybrid); all our
    archs have decoders, so decode cells always run."""
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        cells.append(LONG_500K)
    return cells
