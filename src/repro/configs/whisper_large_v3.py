"""whisper-large-v3 [audio] — enc-dec, 32L(+32 enc) d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866, conv frontend STUB (input_specs supplies precomputed
frame embeddings, 1500 frames). [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    mlp="gelu",
    norm="layernorm",
    attn_bias=True,
    enc_layers=32,
    audio_frames=1500,
)
