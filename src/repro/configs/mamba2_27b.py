"""mamba2-2.7b [ssm] — 64L d_model=2560, attention-free, ssm_state=128,
SSD (state-space duality). [arXiv:2405.21060]

d_inner = 2*2560 = 5120; head_dim 64 -> 80 SSD heads. Runs long_500k
(O(1)-state decode)."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=80,            # SSD heads = d_inner / head_dim
    n_kv_heads=80,
    d_ff=0,                # attention-free, no separate MLP block (mamba2 arch)
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, d_conv=4,
                  chunk=128),
    sub_quadratic=True,
    tie_embeddings=True,
)
