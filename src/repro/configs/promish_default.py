"""The paper's own experimental configuration (§VIII) as a config module —
index hyper-parameters and the dataset grid used by the benchmarks."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PromishConfig:
    m: int = 2                 # random unit vectors per HI structure
    n_scales: int = 5          # L (paper: L=5, w0 = pMax / 2^L)
    buckets_per_point: float = 1.0
    seed: int = 0


PAPER_DEFAULT = PromishConfig()

# Table III — the paper's real-dataset grid (sizes, dictionary, tags/point).
PAPER_REAL_DATASETS = (
    dict(n=10_000, u=5_661, t=12),
    dict(n=30_000, u=6_753, t=13),
    dict(n=50_000, u=7_101, t=13),
    dict(n=70_000, u=7_902, t=14),
    dict(n=1_000_000, u=24_874, t=11),
)

# §VIII synthetic defaults
PAPER_SYNTH = dict(coord_range=10_000.0, u=1_000, t=1)
