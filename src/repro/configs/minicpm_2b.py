"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753, WSD schedule, llama-like. [arXiv:2404.06395; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    head_dim=64,
    mlp="swiglu",
    norm="rmsnorm",
    schedule="wsd",
    tie_embeddings=True,
)
