"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision]

The vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (vision_tokens x vision_dim); the model owns
only the projection + gated cross-attention layers."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    vision_tokens=1601,
    vision_dim=7680,
)
