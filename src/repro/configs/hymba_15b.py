"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16, parallel attention + mamba heads in every layer.
[arXiv:2411.13676; hf]

Runs long_500k: the SSM half carries long-range state; the attention half
uses a sliding window (Hymba's global+local scheme) so decode stays
sub-quadratic."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    head_dim=64,
    hybrid=True,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, n_groups=1, d_conv=4,
                  chunk=128),
    sub_quadratic=True,
)
