"""Config registry: ``get_config(arch_id)`` -> ArchConfig."""
from repro.configs.base import (ALL_CELLS, DECODE_32K, LONG_500K, PREFILL_32K,  # noqa: F401
                                TRAIN_4K, ArchConfig, MoEConfig, ShapeCell,
                                SSMConfig, supported_cells)
from repro.configs.minicpm_2b import CONFIG as MINICPM_2B
from repro.configs.qwen3_32b import CONFIG as QWEN3_32B
from repro.configs.codeqwen15_7b import CONFIG as CODEQWEN15_7B
from repro.configs.starcoder2_7b import CONFIG as STARCODER2_7B
from repro.configs.mamba2_27b import CONFIG as MAMBA2_27B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.llama4_maverick import CONFIG as LLAMA4_MAVERICK
from repro.configs.hymba_15b import CONFIG as HYMBA_15B
from repro.configs.llama32_vision_90b import CONFIG as LLAMA32_VISION_90B
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3

REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in [MINICPM_2B, QWEN3_32B, CODEQWEN15_7B, STARCODER2_7B, MAMBA2_27B,
              OLMOE_1B_7B, LLAMA4_MAVERICK, HYMBA_15B, LLAMA32_VISION_90B,
              WHISPER_LARGE_V3]
}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]
