"""Pipeline parallelism over a mesh axis via shard_map + collective_permute.

GPipe-style schedule: the layer stack is split into ``n_stages`` equal stages
(one per device along the ``stage`` axis); microbatches stream through, and
activations hop stage->stage+1 with ``ppermute``. Bubble fraction is
(S-1)/(M+S-1); the launcher picks M >= 4*S. 1F1B ordering falls out of the
same loop when fwd/bwd are interleaved by jax.grad over the scanned schedule
— we expose the forward schedule (inference/serving pipelines) and a
grad-through-pipeline helper for training.

This is the ``pod``-axis alternative to pure DP when a model's layer stack
does not fit one pod's HBM even fully FSDP-sharded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn, stage_params, microbatches, *, axis_name: str):
    """Run microbatches through the stage pipeline (inside shard_map).

    stage_fn(params_local, x) -> y      : one stage's computation
    stage_params                        : this device's stage slice
    microbatches (M, ...)               : local microbatch stream (stage 0
                                          consumes; other stages ignore input)
    Returns (M, ...) outputs valid on the LAST stage (zeros elsewhere).
    """
    idx = jax.lax.axis_index(axis_name)
    n_stages = jax.lax.psum(1, axis_name)   # axis size (jax.lax.axis_size is newer jax)
    m = microbatches.shape[0]
    steps = m + n_stages - 1
    x_shape = microbatches.shape[1:]

    def body(carry, t):
        state, outputs = carry                       # state: in-flight act
        inject = jnp.where(t < m, t, 0)
        x_in = jnp.where(idx == 0,
                         microbatches[inject],
                         state)
        y = stage_fn(x_in, t)
        # pass activation to the next stage (ring; last->0 value is unused)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        state_next = jax.lax.ppermute(y, axis_name, perm)
        out_t = t - (n_stages - 1)
        is_out = (out_t >= 0) & (idx == n_stages - 1)
        outputs = jnp.where(
            is_out,
            outputs.at[jnp.maximum(out_t, 0)].set(y),
            outputs)
        return (state_next, outputs), None

    state0 = jnp.zeros(x_shape, microbatches.dtype)
    out0 = jnp.zeros((m, *x_shape), microbatches.dtype)
    (_, outputs), _ = jax.lax.scan(body, (state0, out0), jnp.arange(steps))
    return outputs


def make_pipelined_apply(layer_fn, n_layers: int, n_stages: int,
                         axis_name: str = "pod"):
    """Wrap a per-layer fn into a stage fn scanning its local layer slice.

    The caller shard_maps the result with stacked layer params partitioned
    on their leading (layer) axis over ``axis_name``:
        params leaves (n_layers, ...) -> per-device (n_layers/n_stages, ...).
    """
    layers_per_stage = n_layers // n_stages

    def stage_fn(params_local, x, t):
        del t

        def body(h, p_l):
            return layer_fn(p_l, h), None

        y, _ = jax.lax.scan(body, x, params_local)
        return y

    def apply(params_stacked, microbatches):
        def inner(p_loc, mb):
            return pipeline_forward(
                functools.partial(stage_fn, p_loc), p_loc, mb,
                axis_name=axis_name)
        return inner

    return apply, layers_per_stage


def stage_partition_spec(axis_name: str = "pod") -> P:
    """PartitionSpec for stacked layer params: layer axis over the stage axis."""
    return P(axis_name)
