"""AdamW with WSD / cosine schedules, global-norm clipping, and configurable
optimizer-state dtypes.

State-dtype note (large-arch memory): fp32 m/v costs 8 bytes/param — at
llama4-maverick scale (~400B params) that alone is 3.2 TB. ``state_dtype=
bfloat16`` halves it with negligible quality impact at these batch sizes
(error feedback lives in the momenta); DESIGN.md records this as the default
for >=90B archs.

WSD (Warmup-Stable-Decay) is the minicpm schedule: linear warmup -> flat
stable phase -> short 1-sqrt decay tail.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    decay_frac: float = 0.1          # WSD: fraction of steps in the decay tail
    schedule: str = "cosine"         # "cosine" | "wsd"
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"     # "float32" | "bfloat16"


def lr_at(cfg: OptimizerConfig, step):
    """Schedule value at ``step`` (jit-safe)."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "wsd":
        decay_steps = jnp.maximum(cfg.total_steps * cfg.decay_frac, 1.0)
        decay_start = cfg.total_steps - decay_steps
        in_decay = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        decay = 1.0 - (1.0 - cfg.min_lr_frac) * jnp.sqrt(in_decay)
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * warm * decay


def init_opt_state(params, cfg: OptimizerConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    def zeros(p):
        return jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
