"""Host-side training loop with the fault-tolerance contract:

  * restore-from-latest on start (params, optimizer, data position);
  * rolling atomic checkpoints (repro.ckpt);
  * SIGTERM/SIGINT => checkpoint-now + clean exit (preemption handling);
  * straggler watch: EWMA step time, steps slower than ``straggler_sigma``
    deviations are counted and logged — on a fleet this signal feeds the
    re-dispatch policy; the loop itself never blocks on it;
  * metrics jsonl stream.
"""
from __future__ import annotations

import dataclasses
import json
import signal
import time
from typing import Callable

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager, load_checkpoint
from repro.data.token_pipeline import TokenPipeline


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    straggler_sigma: float = 3.0
    metrics_path: str | None = None


class TrainLoop:
    def __init__(self, cfg: LoopConfig, step_fn: Callable, pipeline: TokenPipeline,
                 init_state: Callable):
        """step_fn(state, batch) -> (state, metrics); init_state() -> pytree
        {"params", "opt", ...}. step_fn should be jitted & donating."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.pipeline = pipeline
        self.init_state = init_state
        self._preempted = False

    def _handle_preemption(self, signum, frame):
        self._preempted = True

    def run(self, dp_rank: int = 0, dp_size: int = 1):
        cfg = self.cfg
        mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep, every=cfg.ckpt_every)
        state = self.init_state()
        start_step = 0
        latest = mgr.latest()
        if latest is not None:
            state, start_step, extra = load_checkpoint(latest, state)
            start_step = int(extra.get("next_step", start_step))

        old_term = signal.signal(signal.SIGTERM, self._handle_preemption)
        old_int = signal.signal(signal.SIGINT, self._handle_preemption)

        ema_t, ema_var = None, 0.0
        stragglers = 0
        metrics_f = open(cfg.metrics_path, "a") if cfg.metrics_path else None
        history = []
        try:
            for step in range(start_step, cfg.total_steps):
                batch = self.pipeline.batch_at(step, dp_rank, dp_size)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(state)[0])
                dt = time.perf_counter() - t0

                if ema_t is None:
                    ema_t = dt
                else:
                    dev = dt - ema_t
                    if step > 5 and ema_var > 0 and \
                            dev > self.cfg.straggler_sigma * np.sqrt(ema_var):
                        stragglers += 1
                    ema_t = 0.9 * ema_t + 0.1 * dt
                    ema_var = 0.9 * ema_var + 0.1 * dev * dev

                rec = {"step": step, "time_s": dt, "stragglers": stragglers,
                       **{k: float(np.asarray(v)) for k, v in metrics.items()}}
                history.append(rec)
                if metrics_f and step % cfg.log_every == 0:
                    metrics_f.write(json.dumps(rec) + "\n")
                    metrics_f.flush()

                mgr.maybe_save(step + 1, state, extra={"next_step": step + 1})
                if self._preempted:
                    mgr.maybe_save(step + 1, state,
                                   extra={"next_step": step + 1}, force=True)
                    break
        finally:
            signal.signal(signal.SIGTERM, old_term)
            signal.signal(signal.SIGINT, old_int)
            if metrics_f:
                metrics_f.close()
        return state, history
