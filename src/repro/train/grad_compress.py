"""Int8 error-feedback gradient compression for the slow (inter-pod) axis.

The DP gradient all-reduce over the pod axis crosses DCN/optical links an
order of magnitude slower than intra-pod ICI. We compress it 4x: per-leaf
symmetric int8 quantisation with an **error-feedback** buffer (Seide et al.;
EF-SGD) so quantisation error is re-injected next step instead of lost —
keeps convergence unbiased to first order.

Two entry points:
  * ``ef_compress`` / residual math — pure, testable anywhere;
  * ``compressed_psum`` — the shard_map form: quantise, ``psum`` the int8
    payload (as int32 partial sums), dequantise the mean. Use inside
    ``shard_map`` over the "pod" axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g):
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads, error_buf):
    """Error-feedback quantisation of a gradient pytree.

    Returns (dequantised grads, new error buffer). ``error_buf`` pytree
    matches grads (fp32); pass zeros on step 0.
    """
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq)

    out = jax.tree.map(leaf, grads, error_buf)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def init_error_buf(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, error_buf, axis_name: str):
    """shard_map body: int8-compressed mean-all-reduce over ``axis_name``.

    Wire-honest for the small pod counts this axis has (2-8): each
    participant quantises (with error feedback) and **all_gathers the int8
    payload** plus one fp32 scale per leaf — 1 byte/element/peer on the wire
    vs 4 for an fp32 ring; dequantise + mean happen locally, so the result is
    exactly mean_p(q_p * scale_p) on every shard.
    """
    p = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        new_err = g32 - q.astype(jnp.float32) * scale
        q_all = jax.lax.all_gather(q, axis_name)              # (P, ...) int8
        s_all = jax.lax.all_gather(scale, axis_name)          # (P,)   fp32
        deq = q_all.astype(jnp.float32) * s_all.reshape(
            (-1,) + (1,) * q.ndim)
        return (deq.sum(axis=0) / p).astype(g.dtype), new_err

    out = jax.tree.map(leaf, grads, error_buf)
    red = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return red, err
