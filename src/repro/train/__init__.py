"""repro.train — optimizer, schedules, grad compression, PP, train loop."""
from repro.train.optimizer import (OptimizerConfig, adamw_update,  # noqa: F401
                                   init_opt_state, lr_at)
