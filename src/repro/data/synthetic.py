"""Synthetic dataset generator exactly per paper §VIII:

  * each coordinate uniform in [0, 10000]
  * each point tagged with t keywords drawn from a dictionary of size U
    (uniformly, like the paper's complexity model §VII).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import KeywordDataset, make_dataset


def synthetic_dataset(n: int, d: int, u: int, t: int = 1, *, seed: int = 0,
                      coord_range: float = 10_000.0) -> KeywordDataset:
    rng = np.random.default_rng(seed)
    points = rng.uniform(0.0, coord_range, size=(n, d)).astype(np.float32)
    # t distinct keywords per point
    if t == 1:
        kws = rng.integers(0, u, size=(n, 1))
    else:
        kws = np.argsort(rng.random((n, u)), axis=1)[:, :t]
    keywords = [row.tolist() for row in kws]
    return make_dataset(points, keywords, n_keywords=u)


def random_queries(dataset: KeywordDataset, q: int, n_queries: int, *,
                   seed: int = 0, require_nonempty: bool = True) -> list[list[int]]:
    """Random q-keyword queries from the dictionary (paper §VIII), keeping only
    keywords that tag >=1 point so every query has at least one candidate."""
    rng = np.random.default_rng(seed)
    present = np.flatnonzero(np.diff(dataset.ikp.offsets) > 0) if require_nonempty \
        else np.arange(dataset.n_keywords)
    if len(present) < q:
        raise ValueError("not enough populated keywords for query size")
    return [sorted(rng.choice(present, size=q, replace=False).tolist())
            for _ in range(n_queries)]
