"""Synthetic dataset generator exactly per paper §VIII:

  * each coordinate uniform in [0, 10000]
  * each point tagged with t keywords drawn from a dictionary of size U
    (uniformly, like the paper's complexity model §VII).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import KeywordDataset, make_dataset


def synthetic_dataset(n: int, d: int, u: int, t: int = 1, *, seed: int = 0,
                      coord_range: float = 10_000.0) -> KeywordDataset:
    rng = np.random.default_rng(seed)
    points = rng.uniform(0.0, coord_range, size=(n, d)).astype(np.float32)
    # t distinct keywords per point
    if t == 1:
        kws = rng.integers(0, u, size=(n, 1))
    else:
        kws = np.argsort(rng.random((n, u)), axis=1)[:, :t]
    keywords = [row.tolist() for row in kws]
    return make_dataset(points, keywords, n_keywords=u)


def random_queries(dataset: KeywordDataset, q: int, n_queries: int, *,
                   seed: int = 0, require_nonempty: bool = True) -> list[list[int]]:
    """Random q-keyword queries from the dictionary (paper §VIII), keeping only
    keywords that tag >=1 point so every query has at least one candidate."""
    rng = np.random.default_rng(seed)
    present = np.flatnonzero(np.diff(dataset.ikp.offsets) > 0) if require_nonempty \
        else np.arange(dataset.n_keywords)
    if len(present) < q:
        raise ValueError("not enough populated keywords for query size")
    return [sorted(rng.choice(present, size=q, replace=False).tolist())
            for _ in range(n_queries)]


def synthetic_attrs(n: int, *, seed: int = 0, price_range: float = 100.0,
                    n_categories: int = 8) -> dict:
    """Per-point attribute columns for filtered-NKS workloads: a uniform
    numeric ``price`` (so a threshold at ``price_range * s`` hits selectivity
    ~s exactly) and a categorical ``category``."""
    rng = np.random.default_rng(seed + 101)
    return {
        "price": rng.uniform(0.0, price_range, size=n),
        "category": rng.integers(0, n_categories, size=n, dtype=np.int64),
    }


def attach_attrs(dataset: KeywordDataset, *, seed: int = 0,
                 price_range: float = 100.0,
                 n_categories: int = 8) -> KeywordDataset:
    """The same corpus with synthetic attribute columns attached."""
    return dataclasses.replace(
        dataset, attrs=synthetic_attrs(dataset.n, seed=seed,
                                       price_range=price_range,
                                       n_categories=n_categories))


def synthetic_tenants(tenant_sizes: "dict[str, int]", d: int, u: int,
                      t: int = 2, *, seed: int = 0,
                      with_attrs: bool = True) -> KeywordDataset:
    """A multi-tenant corpus: one synthetic sub-corpus per tenant, each with
    its own keyword namespace of size ``u``, packed via
    :func:`repro.core.types.merge_tenants`."""
    from repro.core.types import merge_tenants
    corpora = {}
    for i, (name, n) in enumerate(tenant_sizes.items()):
        ds = synthetic_dataset(n=n, d=d, u=u, t=t, seed=seed + 7 * i)
        corpora[name] = {
            "points": ds.points,
            "keywords": [ds.kw.row(j).tolist() for j in range(ds.n)],
            "n_keywords": u,
            "attrs": synthetic_attrs(n, seed=seed + 13 * i) if with_attrs
            else None,
        }
    return merge_tenants(corpora)
