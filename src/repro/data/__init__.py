"""repro.data — dataset generators and the two data pipelines.

Two distinct "pipelines" live here; the names keep them apart:

  * :mod:`repro.data.token_pipeline` — the deterministic *training token*
    pipeline feeding the embedder trainer (counter-based PRNG, elastic
    resharding).
  * :mod:`repro.data.ingest` — the *corpus ingestion* pipeline: raw
    documents through a persistent job queue, embed workers, and WAL
    group-committed batch inserts into a live engine.
"""
from repro.data.synthetic import synthetic_dataset, random_queries  # noqa: F401
from repro.data.flickr_like import flickr_like_dataset  # noqa: F401
from repro.data.ingest import (  # noqa: F401
    IngestPipeline, IngestWorker, JobStore, ProjectionEmbedder,
    corpus_from_documents, flickr_like_documents,
)
