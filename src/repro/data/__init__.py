"""repro.data — dataset generators and the sharded training pipeline."""
from repro.data.synthetic import synthetic_dataset, random_queries  # noqa: F401
from repro.data.flickr_like import flickr_like_dataset  # noqa: F401
