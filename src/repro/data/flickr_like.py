"""Flickr-like "real" dataset generator.

The paper's real datasets are grayscale-histogram features of Flickr images
tagged with user keywords (Table III: up to 24,874 unique keywords, ~11-14
tags per point). Offline we synthesise data with the same statistics:

  * points drawn from a Gaussian-mixture (images cluster by visual content),
  * keyword frequencies follow a Zipf law (tag popularity is heavy-tailed),
  * keyword-cluster affinity: tags correlate with clusters (similar photos
    share tags), which is what makes NKS queries meaningful.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import KeywordDataset, make_dataset


def flickr_like_dataset(n: int, d: int, u: int, t: int = 11, *,
                        n_clusters: int = 64, zipf_a: float = 1.3,
                        affinity: float = 0.7, seed: int = 0) -> KeywordDataset:
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.0, 255.0, size=(n_clusters, d)).astype(np.float32)
    scales = rng.uniform(4.0, 24.0, size=(n_clusters, 1)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    points = centers[assign] + rng.standard_normal((n, d)).astype(np.float32) * scales[assign]

    # Zipf keyword popularity over the dictionary.
    ranks = np.arange(1, u + 1, dtype=np.float64)
    pop = ranks ** (-zipf_a)
    pop /= pop.sum()
    # cluster-specific preferred keyword pools
    pool_size = max(t * 4, 16)
    cluster_pools = np.stack([
        rng.choice(u, size=pool_size, replace=False, p=pop) for _ in range(n_clusters)
    ])

    keywords = []
    for i in range(n):
        n_aff = int(round(t * affinity))
        pool = cluster_pools[assign[i]]
        aff = rng.choice(pool, size=min(n_aff, len(pool)), replace=False)
        glob = rng.choice(u, size=t - len(aff), replace=True, p=pop)
        keywords.append(np.unique(np.concatenate([aff, glob])).tolist())
    return make_dataset(points, keywords, n_keywords=u)
