"""Document-to-corpus ingestion: a job-queue worker pipeline feeding the
streaming plane.

The paper's real workloads start from raw documents (Flickr images tagged
with user keywords), not from pre-built ``KeywordDataset`` arrays. This
module is the missing front half: documents enter a persistent
:class:`JobStore`, state-machine workers (:class:`IngestWorker`) pull them
through an extract/embed stage and batch-insert the results into a live
:class:`~repro.serve.engine.NKSEngine` (directly, or through the serving
runtime so pipeline inserts coalesce with launcher ingests).

Job lifecycle (every transition is journaled, fsync'd, and replayable)::

    pending --claim--> claimed --embed--> embedded --intent--> inserted
       ^                  |                  |                    |
       |   (lease expiry / retryable error, attempts < max)      ack
       +------------------+------------------+------------+      |
       |                                                  |      v
       +--[attempts exhausted]--> failed                 done <--+

  * **claim** is lease-based: a worker that dies mid-batch loses its lease
    and the jobs are reclaimed by any live worker (``claim`` lazily releases
    expired leases). Each claim counts one attempt; a job whose attempts
    exhaust ``max_attempts`` lands in terminal ``failed``.
  * **retry** is backoff-scheduled: a released job becomes claimable again
    at ``now + backoff_s * 2^(attempts-1)``.
  * **insert** is exactly-once via a durable *intent*: before touching the
    engine the worker journals an intent carrying the engine's
    ``next_external_id`` horizon (sampled inside the store lock, atomically
    with the fence check), inserts the whole batch as one op inside
    ``NKSEngine.ingest_group()`` (one WAL fsync barrier for the batch), and
    acks only after the barrier. The open intent doubles as the insert
    mutex — at most one batch is ever in flight, so recovery can decide
    "did the batch land?" by comparing the recovered engine's external-id
    horizon against the intent: covered => ack without re-inserting
    (exactly-once above the ack horizon); not covered => the jobs revert to
    ``pending`` and are re-embedded/re-inserted (at-least-once below it).
    The embedder is deterministic, so a re-run produces bit-identical
    points.

Crash sites (``serve.faults`` points, armed by the fault suite):
``claim`` / ``embed`` / ``insert`` / ``ack`` — one per state-machine window,
each exercising a different recovery path above.

Determinism: the clock is injectable (leases, backoff), workers expose a
single-cycle :meth:`IngestWorker.step`, and the default
:class:`ProjectionEmbedder` is a pure function of the document payload —
the test suite drives arbitrary interleavings of worker progress and
crashes and asserts the final corpus is permutation-identical to a no-fault
build over the same documents.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.types import KeywordDataset, make_dataset, merge_tenants
from repro.serve.faults import NO_FAULTS, FaultPlan, InjectedCrash

# ------------------------------------------------------------------ documents

#: Job states (the journal speaks these strings; keep them stable).
PENDING = "pending"
CLAIMED = "claimed"
EMBEDDED = "embedded"
INSERTED = "inserted"
DONE = "done"
FAILED = "failed"

_TERMINAL = (DONE, FAILED)
_LEGAL = {
    (PENDING, CLAIMED),
    (CLAIMED, EMBEDDED),
    (EMBEDDED, INSERTED),
    (INSERTED, DONE),
    # retry / lease-reclaim paths back to pending:
    (CLAIMED, PENDING), (EMBEDDED, PENDING), (INSERTED, PENDING),
    # attempt exhaustion from any in-flight state:
    (CLAIMED, FAILED), (EMBEDDED, FAILED), (INSERTED, FAILED),
}


class InvalidTransition(RuntimeError):
    """An illegal job state transition (or wrong-owner mutation)."""


class LeaseLost(InvalidTransition):
    """The worker's lease on a job was reclaimed — its staged work is void."""


class IntentBusy(RuntimeError):
    """Another batch's insert intent is open (the insert stage is a
    lease-guarded mutex: one batch in flight at a time)."""

    def __init__(self, intent_id: int, expired: bool):
        super().__init__(f"intent {intent_id} open "
                         f"({'expired' if expired else 'live'})")
        self.intent_id = intent_id
        self.expired = expired


class SinkIndeterminate(RuntimeError):
    """The sink cannot say whether the batch landed (the runtime crashed
    mid-run, or its ticket never reached a terminal status). The worker must
    NOT resolve the intent from the current horizon — the op may still land
    later, and releasing the jobs now would retry a batch that also lands,
    duplicating it. Leave the intent open; lease expiry (or
    ``IngestPipeline.recover`` after a restart) reconciles from a horizon
    that post-dates the op's last possible execution instant."""


def flickr_like_documents(n: int, d_raw: int = 32, u: int = 30, t: int = 3, *,
                          n_clusters: int = 12, zipf_a: float = 1.3,
                          affinity: float = 0.7, seed: int = 0,
                          tenants: Sequence[str] | None = None,
                          with_attrs: bool = True
                          ) -> tuple[list[dict], list[str]]:
    """Raw documents with ``flickr_like`` statistics, plus the tag vocabulary.

    Each document is a JSON-serializable dict — the form the :class:`JobStore`
    journals — carrying a raw feature payload (``d_raw``-dim histogram, drawn
    from a Gaussian mixture), ``t``-ish Zipf-popular tag *strings* with
    cluster affinity, optional ``attrs`` (price/category) and an optional
    ``tenant``. The embedder projects payloads down to index points and maps
    tags through the returned vocabulary, so a corpus built from these
    documents has the same shape as :func:`flickr_like_dataset`.
    """
    rng = np.random.default_rng(seed)
    vocab = [f"tag{i:03d}" for i in range(u)]
    centers = rng.uniform(0.0, 255.0, size=(n_clusters, d_raw))
    scales = rng.uniform(4.0, 24.0, size=(n_clusters, 1))
    assign = rng.integers(0, n_clusters, size=n)
    payloads = centers[assign] + rng.standard_normal((n, d_raw)) * scales[assign]

    ranks = np.arange(1, u + 1, dtype=np.float64)
    pop = ranks ** (-zipf_a)
    pop /= pop.sum()
    pool_size = max(t * 4, 16)
    pools = np.stack([rng.choice(u, size=pool_size, replace=False, p=pop)
                      for _ in range(n_clusters)])

    docs = []
    for i in range(n):
        n_aff = int(round(t * affinity))
        pool = pools[assign[i]]
        aff = rng.choice(pool, size=min(n_aff, len(pool)), replace=False)
        glob = rng.choice(u, size=t - len(aff), replace=True, p=pop)
        tags = sorted({vocab[v] for v in np.concatenate([aff, glob])})
        doc = {
            "doc_id": f"doc-{i:06d}",
            "payload": np.asarray(payloads[i], np.float32).tolist(),
            "tags": tags,
        }
        if with_attrs:
            doc["attrs"] = {
                "price": float(rng.uniform(0.0, 100.0)),
                "category": int(rng.integers(0, 8)),
            }
        if tenants:
            doc["tenant"] = str(tenants[int(rng.integers(0, len(tenants)))])
        docs.append(doc)
    return docs, vocab


@dataclasses.dataclass(frozen=True)
class IngestRecord:
    """One embedded document: what the insert stage commits to the engine.
    ``keywords`` are vocabulary (tenant-*local* on a namespaced corpus) ids —
    the sink resolves them to global dictionary slots, same convention as
    ``launch/serve.py`` inserts."""

    doc_id: str
    point: np.ndarray
    keywords: list[int]
    attrs: dict | None
    tenant: str | None


class ProjectionEmbedder:
    """Deterministic extract/embed stage: a fixed seeded random projection of
    the raw payload plus a tag-string -> vocabulary-id lookup.

    Determinism is a pipeline correctness requirement, not a convenience: a
    job reclaimed after a worker crash is re-embedded from its document, and
    the exactly-once story needs that re-run to produce bit-identical
    points. ``extract`` is a pure function of the document.
    """

    def __init__(self, d_out: int, vocab: Sequence[str], *, d_raw: int,
                 seed: int = 0):
        self.d_out = int(d_out)
        self.d_raw = int(d_raw)
        self.vocab = {tag: i for i, tag in enumerate(vocab)}
        rng = np.random.default_rng(seed)
        self._w = (rng.standard_normal((self.d_raw, self.d_out))
                   / np.sqrt(self.d_raw)).astype(np.float32)

    def _point(self, payload: np.ndarray) -> np.ndarray:
        return payload @ self._w

    def extract(self, doc: dict) -> IngestRecord:
        payload = np.asarray(doc["payload"], dtype=np.float32)
        if payload.shape != (self.d_raw,):
            raise ValueError(f"payload must be ({self.d_raw},), "
                             f"got {payload.shape}")
        tags = doc.get("tags") or ()
        try:
            kws = sorted({self.vocab[tag] for tag in tags})
        except KeyError as e:
            raise ValueError(f"unknown tag {e.args[0]!r} in "
                             f"{doc.get('doc_id')!r}") from None
        if not kws:
            raise ValueError(f"document {doc.get('doc_id')!r} has no tags")
        return IngestRecord(doc_id=str(doc["doc_id"]),
                            point=self._point(payload),
                            keywords=kws, attrs=doc.get("attrs"),
                            tenant=doc.get("tenant"))


class ModelEmbedder(ProjectionEmbedder):
    """Model-backed embed stage: payloads run through an ``embed_fn``
    ((B, d_raw) -> (B, d_out) features — e.g. a partial over
    ``repro.models.api.model_api(cfg).embed`` with trained params) instead
    of the fixed projection. The tag/attrs/tenant handling is inherited.
    The callable must be deterministic for the recovery story to hold."""

    def __init__(self, embed_fn: Callable[[np.ndarray], np.ndarray],
                 d_out: int, vocab: Sequence[str], *, d_raw: int):
        super().__init__(d_out, vocab, d_raw=d_raw)
        self._embed_fn = embed_fn

    def _point(self, payload: np.ndarray) -> np.ndarray:
        out = np.asarray(self._embed_fn(payload[None, :]), np.float32)[0]
        if out.shape != (self.d_out,):
            raise ValueError(f"embed_fn returned {out.shape}, "
                             f"expected ({self.d_out},)")
        return out


def corpus_from_documents(docs: Sequence[dict], embedder
                          ) -> tuple[KeywordDataset, list[str]]:
    """Build a frozen corpus from documents — the *static reference* the
    pipeline's end-to-end differential compares against.

    Returns ``(dataset, doc_ids)`` with ``doc_ids[i]`` naming row ``i``.
    Tenant-tagged documents pack through ``merge_tenants`` (sorted tenant
    order, so the namespace layout is deterministic); row order is then
    by-tenant, not document order — which is why differentials compare
    doc-id-canonicalized answer sets, never raw external ids.
    """
    recs = [embedder.extract(d) for d in docs]
    u = len(embedder.vocab)
    if any(r.tenant is not None for r in recs):
        if not all(r.tenant is not None for r in recs):
            raise ValueError("mixed tenant-tagged and untagged documents")
        corpora: dict[str, dict] = {}
        order: list[str] = []
        for name in sorted({r.tenant for r in recs}):
            sub = [r for r in recs if r.tenant == name]
            order.extend(r.doc_id for r in sub)
            corpora[name] = {
                "points": np.stack([r.point for r in sub]),
                "keywords": [r.keywords for r in sub],
                "n_keywords": u,
                "attrs": _attr_columns(sub),
            }
        return merge_tenants(corpora), order
    ds = make_dataset(np.stack([r.point for r in recs]),
                      [r.keywords for r in recs], n_keywords=u,
                      attrs=_attr_columns(recs))
    return ds, [r.doc_id for r in recs]


def _attr_columns(recs: Sequence[IngestRecord]) -> dict | None:
    """Per-record attrs dicts -> columnar arrays (None when unattributed)."""
    if recs[0].attrs is None:
        if any(r.attrs is not None for r in recs):
            raise ValueError("mixed attributed and unattributed documents")
        return None
    names = sorted(recs[0].attrs)
    return {name: np.asarray([r.attrs[name] for r in recs])
            for name in names}


# ------------------------------------------------------------------ job store
@dataclasses.dataclass
class Job:
    """One document's journey through the pipeline. Mutated only by the
    owning :class:`JobStore` — treat instances handed out by ``claim`` as
    read-only snapshots."""

    job_id: int
    doc: dict
    state: str = PENDING
    attempts: int = 0
    not_before: float = 0.0
    lease_until: float = 0.0
    worker: str | None = None
    error: str | None = None
    ext_id: int | None = None


@dataclasses.dataclass
class Intent:
    """A durable insert intent: the batch's jobs plus the engine external-id
    horizon recorded *before* the insert ran. Recovery compares the horizon
    against the recovered engine to decide applied-vs-reverted."""

    intent_id: int
    worker: str
    job_ids: list[int]
    first_ext: int
    lease_until: float

    @property
    def count(self) -> int:
        return len(self.job_ids)


@dataclasses.dataclass
class StoreStats:
    """Lifetime counters (rebuilt from the journal on open)."""

    added: int = 0
    claims: int = 0          # claim batches handed out
    claimed_jobs: int = 0
    reclaims: int = 0        # jobs yanked off an expired lease
    retries: int = 0         # jobs released back to pending (any reason)
    exhausted: int = 0       # jobs that hit terminal failed
    intents: int = 0
    acked_jobs: int = 0


class JobStore:
    """Persistent job queue: an append-only JSONL journal of state
    transitions, replayed on open. Thread-safe; the clock is injectable so
    the test suite owns lease expiry and backoff deterministically.

    Durability: with ``sync=True`` (default) every journal append is
    fsync'd before the call returns — the ``intent`` record in particular
    must hit disk before the engine insert it fences. A torn tail (crash
    mid-append) is truncated on open, mirroring the engine WAL's recovery
    contract.
    """

    def __init__(self, path: str, *, lease_s: float = 30.0,
                 max_attempts: int = 5, backoff_s: float = 0.05,
                 sync: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        self.path = str(path)
        self.lease_s = float(lease_s)
        self.max_attempts = int(max_attempts)
        self.backoff_s = float(backoff_s)
        self._sync = bool(sync)
        self.clock = clock
        self.jobs: dict[int, Job] = {}
        self.stats = StoreStats()
        self._intent: Intent | None = None
        self._next_job = 0
        self._next_intent = 0
        self._lock = threading.RLock()
        self._replay()
        self._f = open(self.path, "ab")

    # ------------------------------------------------------------- journal
    def _replay(self) -> None:
        if not os.path.exists(self.path):
            return
        good = 0
        with open(self.path, "rb") as f:
            blob = f.read()
        # Only newline-terminated lines are candidates: a record's append is
        # one write of json+"\n", so a tail without its newline is torn even
        # if the JSON happens to parse.
        for line in blob.split(b"\n")[:-1]:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break               # torn tail: crash mid-append
            self._apply(rec)
            good += len(line) + 1
        if good < len(blob):
            with open(self.path, "rb+") as f:
                f.truncate(good)
                f.flush()
                os.fsync(f.fileno())

    def _apply(self, rec: dict) -> None:
        """Re-apply one journaled transition (already validated when it was
        written — replay trusts the history)."""
        t = rec["t"]
        if t == "add":
            jid = int(rec["id"])
            self.jobs[jid] = Job(job_id=jid, doc=rec["doc"],
                                 not_before=float(rec.get("not_before", 0.0)))
            self._next_job = max(self._next_job, jid + 1)
            self.stats.added += 1
        elif t == "claim":
            for jid in rec["ids"]:
                j = self.jobs[jid]
                j.state, j.worker = CLAIMED, rec["worker"]
                j.attempts += 1
                j.lease_until = float(rec["lease_until"])
            self.stats.claims += 1
            self.stats.claimed_jobs += len(rec["ids"])
        elif t == "embed":
            for jid in rec["ids"]:
                j = self.jobs[jid]
                j.state = EMBEDDED
                j.lease_until = float(rec["lease_until"])
        elif t == "release":
            for entry in rec["retry"]:
                # per-job [jid, not_before] pairs; bare ids (legacy records)
                # fall back to the record-level value
                jid, nb = entry if isinstance(entry, list) \
                    else (entry, rec["not_before"])
                j = self.jobs[jid]
                j.state, j.worker = PENDING, None
                j.not_before = float(nb)
                j.error = rec.get("error")
            for jid in rec["failed"]:
                j = self.jobs[jid]
                j.state, j.worker = FAILED, None
                j.error = rec.get("error")
            if rec.get("reason") == "lease":
                self.stats.reclaims += len(rec["retry"]) + len(rec["failed"])
            self.stats.retries += len(rec["retry"])
            self.stats.exhausted += len(rec["failed"])
        elif t == "intent":
            iid = int(rec["intent"])
            self._intent = Intent(intent_id=iid, worker=rec["worker"],
                                  job_ids=[int(i) for i in rec["ids"]],
                                  first_ext=int(rec["first_ext"]),
                                  lease_until=float(rec["lease_until"]))
            for jid in self._intent.job_ids:
                j = self.jobs[jid]
                j.state = INSERTED
                j.lease_until = self._intent.lease_until
            self._next_intent = max(self._next_intent, iid + 1)
            self.stats.intents += 1
        elif t == "ack":
            it = self._intent
            for jid, ext in zip(it.job_ids, rec["ext"]):
                j = self.jobs[jid]
                j.state, j.worker, j.ext_id = DONE, None, int(ext)
            self.stats.acked_jobs += len(it.job_ids)
            self._intent = None
        else:
            raise IOError(f"unknown journal record type {t!r}")

    def _log(self, rec: dict) -> None:
        self._f.write(json.dumps(rec).encode() + b"\n")
        self._f.flush()
        if self._sync:
            os.fsync(self._f.fileno())

    # ------------------------------------------------------------ lifecycle
    def _transition(self, job: Job, new: str) -> None:
        if (job.state, new) not in _LEGAL:
            raise InvalidTransition(
                f"job {job.job_id}: illegal transition "
                f"{job.state!r} -> {new!r}")
        job.state = new

    def _owned(self, worker: str, job_ids: Sequence[int],
               states: tuple) -> list[Job]:
        out = []
        for jid in job_ids:
            j = self.jobs[int(jid)]
            if j.worker != worker or j.state not in states:
                raise LeaseLost(
                    f"job {j.job_id}: owned by {j.worker!r} in state "
                    f"{j.state!r}, not by {worker!r} in {states}")
            out.append(j)
        return out

    def add(self, docs: Sequence[dict], *,
            not_before: Sequence[float] | None = None) -> list[int]:
        """Enqueue documents; returns their job ids. Durable on return.
        ``not_before`` (clock timestamps, one per doc) schedules arrivals —
        a job is invisible to ``claim`` until its instant passes, which lets
        a bench materialise a Poisson arrival process up front."""
        with self._lock:
            ids = []
            for i, doc in enumerate(docs):
                jid = self._next_job
                self._next_job += 1
                nb = float(not_before[i]) if not_before is not None else 0.0
                self.jobs[jid] = Job(job_id=jid, doc=doc, not_before=nb)
                rec = {"t": "add", "id": jid, "doc": doc}
                if nb:
                    rec["not_before"] = nb
                self._f.write(json.dumps(rec).encode() + b"\n")
                ids.append(jid)
                self.stats.added += 1
            self._f.flush()
            if self._sync:
                os.fsync(self._f.fileno())
            return ids

    def _reap_expired(self, now: float) -> None:
        """Release every expired lease (the dead-worker reclaim path)."""
        expired = [j for j in self.jobs.values()
                   if j.state in (CLAIMED, EMBEDDED) and j.lease_until <= now]
        if expired:
            self._release_jobs(expired, error="lease expired", reason="lease",
                              now=now, immediate=True)

    def claim(self, worker: str, limit: int = 16) -> list[Job]:
        """Claim up to ``limit`` ready jobs under a fresh lease. Reclaims
        expired leases first, so a dead worker's jobs re-enter circulation
        on the next live claim."""
        with self._lock:
            now = self.clock()
            self._reap_expired(now)
            ready = sorted(
                (j for j in self.jobs.values()
                 if j.state == PENDING and j.not_before <= now),
                key=lambda j: j.job_id)[:max(int(limit), 0)]
            if not ready:
                return []
            lease_until = now + self.lease_s
            for j in ready:
                self._transition(j, CLAIMED)
                j.worker = worker
                j.attempts += 1
                j.lease_until = lease_until
            self._log({"t": "claim", "ids": [j.job_id for j in ready],
                       "worker": worker, "lease_until": lease_until})
            self.stats.claims += 1
            self.stats.claimed_jobs += len(ready)
            return ready

    def mark_embedded(self, worker: str, job_ids: Sequence[int]) -> None:
        """claimed -> embedded (owner-checked); renews the lease."""
        with self._lock:
            jobs = self._owned(worker, job_ids, (CLAIMED,))
            lease_until = self.clock() + self.lease_s
            for j in jobs:
                self._transition(j, EMBEDDED)
                j.lease_until = lease_until
            self._log({"t": "embed", "ids": [j.job_id for j in jobs],
                       "lease_until": lease_until})

    def release(self, worker: str, job_ids: Sequence[int], *,
                error: str) -> None:
        """Give up owned jobs after a retryable failure: back to ``pending``
        at the backoff schedule, or terminal ``failed`` once attempts are
        exhausted."""
        with self._lock:
            jobs = self._owned(worker, job_ids, (CLAIMED, EMBEDDED))
            self._release_jobs(jobs, error=error, reason="error",
                              now=self.clock())

    def _release_jobs(self, jobs: list[Job], *, error: str, reason: str,
                      now: float, immediate: bool = False) -> None:
        retry, failed = [], []
        for j in jobs:
            if j.attempts >= self.max_attempts:
                self._transition(j, FAILED)
                j.error = f"{error} (attempts exhausted: {j.attempts})"
                j.worker = None
                failed.append(j.job_id)
            else:
                self._transition(j, PENDING)
                j.worker = None
                j.error = error
                j.not_before = now if immediate else \
                    now + self.backoff_s * (2.0 ** max(j.attempts - 1, 0))
                retry.append(j.job_id)
        self._log({"t": "release",
                   "retry": [[i, self.jobs[i].not_before] for i in retry],
                   "failed": failed, "error": error, "reason": reason})
        if reason == "lease":
            self.stats.reclaims += len(jobs)
        self.stats.retries += len(retry)
        self.stats.exhausted += len(failed)

    # ---------------------------------------------------------- insert fence
    def open_intent(self) -> Intent | None:
        with self._lock:
            return self._intent

    def record_intent(self, worker: str, job_ids: Sequence[int], *,
                      horizon) -> int:
        """embedded -> inserted, fenced: raises :class:`IntentBusy` while
        another intent is open (live or expired — an expired one must be
        explicitly resolved via ack/release first, because resolving it
        needs the *engine's* id horizon, which the store cannot see).

        ``horizon`` is the engine's ``next_external_id`` — pass the sink
        (anything with a ``next_external_id`` property) or a callable, NOT a
        pre-read integer: the value is sampled *inside* the store lock,
        after the fence check, so no other batch can complete an
        intent->insert->ack cycle between the read and the fence. A stale
        pre-read horizon would let reconciliation mistake the other batch's
        ids for this one's and ack a batch that never landed. (A plain int
        is still accepted for single-threaded unit tests.)"""
        with self._lock:
            if self._intent is not None:
                raise IntentBusy(self._intent.intent_id,
                                 self._intent.lease_until <= self.clock())
            jobs = self._owned(worker, job_ids, (EMBEDDED,))
            if hasattr(horizon, "next_external_id"):
                first_ext = int(horizon.next_external_id)
            elif callable(horizon):
                first_ext = int(horizon())
            else:
                first_ext = int(horizon)
            iid = self._next_intent
            self._next_intent += 1
            lease_until = self.clock() + self.lease_s
            for j in jobs:
                self._transition(j, INSERTED)
                j.lease_until = lease_until
            self._intent = Intent(intent_id=iid, worker=worker,
                                  job_ids=[j.job_id for j in jobs],
                                  first_ext=int(first_ext),
                                  lease_until=lease_until)
            self._log({"t": "intent", "intent": iid,
                       "ids": self._intent.job_ids, "worker": worker,
                       "first_ext": int(first_ext),
                       "lease_until": lease_until})
            self.stats.intents += 1
            return iid

    def _take_intent(self, intent_id: int) -> Intent:
        if self._intent is None or self._intent.intent_id != int(intent_id):
            raise InvalidTransition(
                f"intent {intent_id} is not the open intent "
                f"({self._intent.intent_id if self._intent else None})")
        return self._intent

    def ack_intent(self, intent_id: int, ext_ids: Sequence[int]) -> None:
        """inserted -> done: the batch is durably in the engine (the caller
        observed the WAL barrier, or reconciliation proved the horizon)."""
        with self._lock:
            it = self._take_intent(intent_id)
            if len(ext_ids) != it.count:
                raise InvalidTransition(
                    f"intent {intent_id}: {len(ext_ids)} ext ids for "
                    f"{it.count} jobs")
            for jid, ext in zip(it.job_ids, ext_ids):
                j = self.jobs[jid]
                self._transition(j, DONE)
                j.worker, j.ext_id = None, int(ext)
            self._log({"t": "ack", "intent": intent_id,
                       "ext": [int(e) for e in ext_ids]})
            self.stats.acked_jobs += it.count
            self._intent = None

    def release_intent(self, intent_id: int, *, error: str) -> None:
        """inserted -> pending/failed: the batch provably did NOT land."""
        with self._lock:
            it = self._take_intent(intent_id)
            self._intent = None
            self._release_jobs([self.jobs[i] for i in it.job_ids],
                              error=error, reason="error", now=self.clock())

    # ------------------------------------------------------------- inspection
    def counts(self) -> dict[str, int]:
        out = {s: 0 for s in (PENDING, CLAIMED, EMBEDDED, INSERTED, DONE,
                              FAILED)}
        with self._lock:
            for j in self.jobs.values():
                out[j.state] += 1
        return out

    def drained(self) -> bool:
        with self._lock:
            return all(j.state in _TERMINAL for j in self.jobs.values())

    def next_ready_at(self) -> float | None:
        """Earliest instant any non-terminal job becomes claimable (lease
        expiry or backoff), or None when drained — what a poll loop should
        sleep toward."""
        with self._lock:
            times = [j.not_before if j.state == PENDING else j.lease_until
                     for j in self.jobs.values() if j.state not in _TERMINAL]
            if self._intent is not None:
                times.append(self._intent.lease_until)
            return min(times) if times else None

    def ext_map(self) -> dict[int, str]:
        """external id -> doc_id over completed jobs (the differential's
        id-translation table)."""
        with self._lock:
            return {j.ext_id: str(j.doc["doc_id"])
                    for j in self.jobs.values() if j.state == DONE}

    def close(self) -> None:
        self._f.close()


# -------------------------------------------------------------------- sinks
class EngineSink:
    """Direct engine target: each batch is one atomic ``insert`` inside an
    ``ingest_group()`` scope — one WAL fsync barrier per batch, ack only
    after the barrier (``insert`` returns post-sync)."""

    def __init__(self, engine):
        self.engine = engine

    @property
    def next_external_id(self) -> int:
        return self.engine.next_external_id

    @property
    def dataset(self):
        return self.engine.dataset

    def insert(self, points, keywords, attrs, tenant) -> list[int]:
        with self.engine.ingest_group():
            ext = self.engine.insert(points, keywords, attrs=attrs,
                                     tenant=tenant)
        return [int(e) for e in ext]


class RuntimeSink:
    """Serving-runtime target: batches ride the admission queue as insert
    ops, so pipeline ingest coalesces with launcher ingests into shared WAL
    group commits (the runtime acks only after the run's barrier).

    ``insert`` never abandons an op that could still execute: the op is
    submitted with ``timeout_s`` as its admission deadline and the ticket is
    awaited to a *terminal* status (executed, expired-before-dispatch,
    rejected, or crashed). Giving up on a still-queued op would break
    exactly-once — the worker would reconcile against an unmoved horizon,
    release the intent and retry, and then the original op would land too,
    inserting the batch twice. Terminal non-ok statuses split two ways:
    ``timeout``/``rejected``/``error`` mean the op provably never mutated
    the engine (a plain raise — the worker's reconcile path reverts and
    retries), while ``crashed`` (or a ticket the runtime never resolved
    within the grace window) raises :class:`SinkIndeterminate` — the op's
    durability is unknowable here, so the intent must stay open."""

    def __init__(self, runtime, *, timeout_s: float = 30.0,
                 grace_s: float = 30.0):
        self.runtime = runtime
        self.timeout_s = float(timeout_s)
        self.grace_s = float(grace_s)

    @property
    def next_external_id(self) -> int:
        return self.runtime.engine.next_external_id

    @property
    def dataset(self):
        return self.runtime.engine.dataset

    def insert(self, points, keywords, attrs, tenant) -> list[int]:
        ticket = self.runtime.submit({"op": "insert", "points": points,
                                      "keywords": keywords, "attrs": attrs,
                                      "tenant": tenant},
                                     deadline_s=self.timeout_s)
        # The admission deadline bounds the queued wait (the runtime expires
        # an undispatched op with status "timeout"), so a terminal status
        # normally arrives within timeout_s plus one dispatch. The grace
        # backstop only trips on a wedged runtime — and then the op's fate
        # is genuinely unknowable, which is exactly what SinkIndeterminate
        # tells the worker.
        try:
            resp = ticket.result(timeout=self.timeout_s + self.grace_s)
        except TimeoutError:
            raise SinkIndeterminate(
                f"insert ticket unresolved after "
                f"{self.timeout_s + self.grace_s:.1f}s") from None
        if resp.status == "crashed":
            raise SinkIndeterminate(f"runtime crashed mid-run: {resp.error}")
        if resp.status != "ok":
            raise RuntimeError(f"runtime insert {resp.status}: {resp.error}")
        return [int(i) for i in resp.payload["ids"]]


def _as_sink(target):
    if isinstance(target, (EngineSink, RuntimeSink)):
        return target
    if hasattr(target, "submit"):
        return RuntimeSink(target)
    return EngineSink(target)


def reconcile_intent(store: JobStore, sink, intent: Intent, *,
                     error: str) -> str:
    """Resolve an open intent against the engine's external-id horizon.

    The intent fence guarantees at most one insert was in flight, and the
    engine assigns external ids strictly sequentially — so the recovered
    horizon either never moved past ``first_ext`` (the batch missed the WAL:
    release for retry) or covers the whole batch (it landed: ack with the
    sequential ids, without re-inserting). Returns ``"applied"`` or
    ``"reverted"``.
    """
    if sink.next_external_id >= intent.first_ext + intent.count:
        store.ack_intent(intent.intent_id,
                         list(range(intent.first_ext,
                                    intent.first_ext + intent.count)))
        return "applied"
    store.release_intent(intent.intent_id, error=error)
    return "reverted"


# ------------------------------------------------------------------- workers
@dataclasses.dataclass
class WorkerStats:
    steps: int = 0
    batches_inserted: int = 0
    docs_inserted: int = 0
    embed_failures: int = 0
    transient_faults: int = 0
    sink_indeterminate: int = 0
    intent_busy: int = 0
    lease_lost: int = 0
    reconciled_applied: int = 0
    reconciled_reverted: int = 0


class IngestWorker:
    """One claim -> embed -> insert -> ack cycle per :meth:`step`.

    ``step`` returns False when no work was available (the caller decides
    whether to sleep or advance a fake clock). An :class:`InjectedCrash`
    from any fault point propagates — the worker is "dead" and must not
    clean up (no lease release, no intent resolution); the lease/intent
    expiry machinery recovers its work, exactly as it would for a worker
    *process* killed mid-batch.
    """

    def __init__(self, name: str, store: JobStore, target, embedder, *,
                 batch_docs: int = 16, faults: FaultPlan = NO_FAULTS,
                 clock: Callable[[], float] | None = None):
        self.name = str(name)
        self.store = store
        self.sink = _as_sink(target)
        self.embedder = embedder
        self.batch_docs = int(batch_docs)
        self.faults = faults
        self.clock = clock if clock is not None else store.clock
        self.stats = WorkerStats()
        self._staged: "list[tuple[Job, IngestRecord]] | None" = None

    def step(self) -> bool:
        """Run one unit of work; returns whether any progress was made.
        ``False`` also covers "waiting on another batch's insert fence" —
        callers should treat it as idle (sleep, or advance a fake clock so
        a dead fence-holder's lease can expire)."""
        self.stats.steps += 1
        if self._staged is None and not self._claim_and_embed():
            return self._reconcile_expired_intent()
        if self._staged is None:
            return True                 # progressed without staging a batch
        return self._insert_staged()

    def _reconcile_expired_intent(self) -> bool:
        """With nothing claimable and nothing staged, an *expired* open
        intent may still need resolving — a dead fence-holder's, or this
        worker's own after a :class:`SinkIndeterminate` on the final batch.
        Without this the store could never drain: the intent's jobs are
        neither terminal nor claimable."""
        it = self.store.open_intent()
        if it is None or it.lease_until > self.clock():
            return False
        try:
            outcome = reconcile_intent(self.store, self.sink, it,
                                       error="intent lease expired")
        except InvalidTransition:
            return True                 # another worker resolved it first
        if outcome == "applied":
            self.stats.reconciled_applied += 1
        else:
            self.stats.reconciled_reverted += 1
        return True

    def _claim_and_embed(self) -> bool:
        jobs = self.store.claim(self.name, limit=self.batch_docs)
        if not jobs:
            return False
        try:
            # Crash site "claim": the batch is leased, nothing embedded —
            # death here is recovered purely by lease expiry.
            self.faults.check("claim")
            staged, bad = [], []
            for j in jobs:
                try:
                    staged.append((j, self.embedder.extract(j.doc)))
                except InjectedCrash:
                    raise
                except Exception as e:
                    bad.append((j, f"{type(e).__name__}: {e}"))
            # Crash site "embed": records exist in worker memory only; the
            # journal still says "claimed" — recovery re-embeds after the
            # lease expires (deterministic embedder => identical records).
            self.faults.check("embed")
        except InjectedCrash:
            raise
        except Exception as e:          # transient (InjectedFault et al.)
            self.stats.transient_faults += 1
            self._release_quietly([j.job_id for j in jobs],
                                  f"{type(e).__name__}: {e}")
            return True
        if bad:
            self.stats.embed_failures += len(bad)
            self._release_quietly([j.job_id for j, _ in bad],
                                  "; ".join(err for _, err in bad))
        if not staged:
            return True
        try:
            self.store.mark_embedded(self.name, [j.job_id for j, _ in staged])
        except LeaseLost:
            self.stats.lease_lost += 1
            return True
        self._staged = staged
        return True

    def _release_quietly(self, job_ids: list[int], error: str) -> None:
        try:
            self.store.release(self.name, job_ids, error=error)
        except LeaseLost:
            self.stats.lease_lost += 1

    def _insert_staged(self) -> bool:
        jobs = [j for j, _ in self._staged]
        recs = [r for _, r in self._staged]
        store = self.store
        it = store.open_intent()
        if it is not None:
            if it.lease_until > self.clock():
                # A live batch holds the insert fence; keep ours staged and
                # report idle — if the holder is dead, its lease must be
                # allowed to expire before anyone can move.
                self.stats.intent_busy += 1
                return False
            outcome = reconcile_intent(store, self.sink, it,
                                       error="intent lease expired")
            if outcome == "applied":
                self.stats.reconciled_applied += 1
            else:
                self.stats.reconciled_reverted += 1
        try:
            # The horizon is sampled by the store inside its lock, after the
            # fence check — atomic with the intent, so another batch's full
            # intent->insert->ack cycle cannot slip between read and fence.
            intent = store.record_intent(
                self.name, [j.job_id for j in jobs], horizon=self.sink)
        except IntentBusy:              # lost the fence race; stay staged
            self.stats.intent_busy += 1
            return False
        except LeaseLost:
            self.stats.lease_lost += 1
            self._staged = None
            return True
        try:
            # Crash site "insert": the intent is durable, the engine was
            # never touched — recovery reverts the intent (horizon short).
            self.faults.check("insert")
            ext = self.sink.insert(*self._assemble(recs))
            # Crash site "ack": the batch is past its WAL barrier but the
            # job store never heard — recovery acks from the horizon
            # without re-inserting (exactly-once above the barrier).
            self.faults.check("ack")
        except InjectedCrash:
            raise                       # dead worker: leave the intent open
        except SinkIndeterminate:
            # The sink lost track of the batch (runtime crashed mid-run, or
            # its ticket never went terminal). Reconciling now against the
            # current horizon could release a batch that still lands —
            # duplicating it — so behave like a dead worker: leave the
            # intent open and let lease expiry (or pipeline recovery)
            # reconcile once the op can no longer be in flight.
            self.stats.sink_indeterminate += 1
            self._staged = None
            return True
        except Exception as e:
            # Transient failure somewhere around the insert: decide from
            # the horizon whether it actually landed, exactly like a
            # post-crash recovery would.
            self.stats.transient_faults += 1
            outcome = reconcile_intent(store, self.sink, store.open_intent(),
                                       error=f"{type(e).__name__}: {e}")
            if outcome == "applied":
                self.stats.reconciled_applied += 1
            else:
                self.stats.reconciled_reverted += 1
            self._staged = None
            return True
        store.ack_intent(intent, ext)
        self._staged = None
        self.stats.batches_inserted += 1
        self.stats.docs_inserted += len(jobs)
        return True

    def _assemble(self, recs: list[IngestRecord]):
        """Records -> one engine batch (points, global keywords, attr
        columns, tenant ids). Tenant-local keywords resolve through the
        corpus namespace, per-point — mixed-tenant batches are fine."""
        ds = self.sink.dataset
        points = np.stack([r.point for r in recs]).astype(np.float32)
        ns = ds.tenants
        if ns is not None:
            keywords = [ns.resolve(r.tenant, r.keywords) for r in recs]
            tenant = np.asarray([ns.id_of(r.tenant) for r in recs],
                                dtype=np.int32)
        else:
            keywords = [r.keywords for r in recs]
            tenant = None
        attrs = _attr_columns(recs) if ds.attrs else None
        return points, keywords, attrs, tenant


# ------------------------------------------------------------------ pipeline
class IngestPipeline:
    """Orchestrates N workers over one store and one sink.

    ``target`` is an :class:`~repro.serve.engine.NKSEngine` (direct,
    one WAL group commit per batch) or a
    :class:`~repro.serve.runtime.ServingRuntime` (batches ride the
    admission queue and coalesce with other ingest). Call :meth:`recover`
    once before starting workers when reopening a store after process
    death; then either drive ``pipeline.workers[i].step()`` manually
    (deterministic tests) or :meth:`run` the thread-per-worker loop.
    """

    def __init__(self, store: JobStore, target, embedder, *,
                 workers: int = 2, batch_docs: int = 16,
                 faults: FaultPlan = NO_FAULTS,
                 poll_s: float = 0.002):
        self.store = store
        self.sink = _as_sink(target)
        self.embedder = embedder
        self.poll_s = float(poll_s)
        self.workers = [
            IngestWorker(f"w{i}", store, self.sink, embedder,
                         batch_docs=batch_docs, faults=faults)
            for i in range(int(workers))]
        self.dead: list[str] = []
        self._stop = False

    def recover(self) -> str | None:
        """Startup reconciliation: resolve the open intent left by a dead
        *process* (lease ignored — nothing can still be in flight). Returns
        ``"applied"``, ``"reverted"``, or None when the store is clean.
        Must run before any worker starts."""
        it = self.store.open_intent()
        if it is None:
            return None
        return reconcile_intent(self.store, self.sink, it,
                                error="recovered open intent")

    def _worker_loop(self, worker: IngestWorker, done: threading.Event
                     ) -> None:
        try:
            while not self._stop:
                if self.store.drained():
                    return
                try:
                    progressed = worker.step()
                except InjectedCrash:
                    self.dead.append(worker.name)
                    return
                if not progressed:
                    time.sleep(self.poll_s)
        finally:
            done.set()

    def run(self, *, timeout_s: float = 60.0) -> dict:
        """Thread-per-worker drain loop. Returns a report; ``drained`` is
        False when the store still holds live jobs at the deadline (e.g.
        every worker crashed)."""
        t0 = time.monotonic()
        deadline = t0 + float(timeout_s)
        events = [threading.Event() for _ in self.workers]
        threads = [threading.Thread(target=self._worker_loop, args=(w, ev),
                                    daemon=True)
                   for w, ev in zip(self.workers, events)]
        for t in threads:
            t.start()
        try:
            while time.monotonic() < deadline:
                if all(ev.is_set() for ev in events):
                    break
                if self.store.drained():
                    break
                time.sleep(self.poll_s)
        finally:
            self._stop = True
            for t in threads:
                t.join(timeout=max(deadline - time.monotonic(), 1.0))
        wall = time.monotonic() - t0
        counts = self.store.counts()
        st = self.store.stats
        return {
            "drained": self.store.drained(),
            "wall_s": wall,
            "docs_done": counts[DONE],
            "docs_failed": counts[FAILED],
            "docs_per_s": counts[DONE] / wall if wall > 0 else 0.0,
            "counts": counts,
            "retries": st.retries,
            "reclaims": st.reclaims,
            "exhausted": st.exhausted,
            "dead_workers": list(self.dead),
            "workers": {w.name: dataclasses.asdict(w.stats)
                        for w in self.workers},
        }
