"""Deterministic, resumable, elastic *training token* pipeline.

(Not corpus ingestion — that is ``repro.data.ingest``, the document ->
job-queue -> engine path. This module feeds the embedder trainer.)

Counter-based PRNG (Philox) keyed by (seed, step, dp_rank): any batch is a
pure function of its coordinates, so
  * resume-after-preemption needs only the step counter (stored in ckpt extra),
  * elastic rescale (different dp_size) re-partitions the same global batch —
    global batch content at a given step is identical for any dp_size that
    divides it,
  * no inter-host coordination or shuffle buffers.

The token stream is synthetic (structured Markov-ish noise so losses move) —
slot in a real tokenised corpus by replacing ``_tokens_for_slice``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    extras: tuple[str, ...] = ()          # "patches" / "frames"
    extra_shape: tuple[int, ...] = ()     # per-sample shape of the extra


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg

    def _rng(self, step: int, sample: int) -> np.random.Generator:
        # counter-based: key = seed, counter = (step, sample)
        return np.random.Generator(np.random.Philox(
            key=self.cfg.seed, counter=[0, 0, step, sample]))

    def _tokens_for_slice(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Samples [lo, hi) of the global batch at ``step``."""
        c = self.cfg
        out = np.empty((hi - lo, c.seq_len + 1), dtype=np.int32)
        for i, sample in enumerate(range(lo, hi)):
            rng = self._rng(step, sample)
            # Markov chain over a small per-sample alphabet -> learnable
            alpha = rng.integers(0, c.vocab_size, size=64)
            idx = rng.integers(0, 64, size=c.seq_len + 1)
            drift = rng.integers(0, 3, size=c.seq_len + 1) - 1
            idx = np.abs((idx + np.cumsum(drift)) % 64)
            out[i] = alpha[idx]
        return out

    def batch_at(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> dict:
        """This rank's shard of the global batch at ``step``."""
        c = self.cfg
        if c.global_batch % dp_size:
            raise ValueError("global_batch must divide dp_size")
        per = c.global_batch // dp_size
        lo = dp_rank * per
        toks = self._tokens_for_slice(step, lo, lo + per)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        for name in c.extras:
            rng = self._rng(step, self.cfg.global_batch + lo)
            batch[name] = rng.standard_normal(
                (per, *c.extra_shape)).astype(np.float32)
        return batch
