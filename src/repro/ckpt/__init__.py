"""repro.ckpt — fault-tolerant checkpointing."""
from repro.ckpt.checkpoint import (CheckpointManager, load_checkpoint,  # noqa: F401
                                   save_checkpoint)
