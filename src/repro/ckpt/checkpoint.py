"""Fault-tolerant checkpointing.

Design constraints from the 1000+-node deployment story:
  * **atomic**: write to ``<dir>/.tmp-<step>``, fsync, then rename — a
    preempted writer can never leave a half checkpoint that restore will pick;
  * **verifiable**: a manifest records per-leaf sha256, shape, dtype; restore
    verifies before any state is touched;
  * **mesh-free / elastic**: leaves are saved as full (unsharded) host arrays
    keyed by pytree path. Resume may use a *different* mesh: the trainer
    ``device_put``s each leaf with the new sharding (resharding happens at
    load, so scaling from N to M pods is a restart, not a migration);
  * **rolling**: ``CheckpointManager`` keeps the newest k checkpoints.

For multi-controller deployments each host writes only the shards it owns
(addressable_shards) into a per-host file; offline here, process 0 owns all.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory: str, step: int, state, extra: dict | None = None) -> str:
    """Atomically persist ``state`` (any pytree of arrays) at ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp-{step}-", dir=directory)
    try:
        leaves, _ = _flatten_with_paths(state)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        arrays = {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            manifest["leaves"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            }
        data_path = os.path.join(tmp, "arrays.npz")
        with open(data_path, "wb") as f:
            np.savez(f, **{k.replace("/", "__"): v for k, v in arrays.items()})
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_checkpoint(path: str, like, *, shardings=None, verify: bool = True):
    """Restore a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of NamedSharding matching ``like`` — each
    leaf is device_put with it (elastic resume onto any mesh).
    Returns (state, step, extra).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    raw = np.load(os.path.join(path, "arrays.npz"))
    like_leaves, treedef = _flatten_with_paths(like)
    sh_leaves = None
    if shardings is not None:
        sh_leaves, _ = _flatten_with_paths(shardings)
    out = {}
    for key, leaf_like in like_leaves.items():
        arr = raw[key.replace("/", "__")]
        meta = manifest["leaves"][key]
        if verify:
            got = hashlib.sha256(arr.tobytes()).hexdigest()
            if got != meta["sha256"]:
                raise IOError(f"checksum mismatch for leaf {key} in {path}")
        if list(arr.shape) != list(leaf_like.shape):
            raise ValueError(f"leaf {key}: ckpt shape {arr.shape} != "
                             f"model shape {leaf_like.shape}")
        if sh_leaves is not None:
            out[key] = jax.device_put(arr, sh_leaves[key])
        else:
            out[key] = jax.numpy.asarray(arr, dtype=leaf_like.dtype)
    state = jax.tree_util.tree_unflatten(
        treedef, [out[k] for k in like_leaves])
    return state, manifest["step"], manifest["extra"]


def find_latest(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    return os.path.join(directory, steps[-1]) if steps else None


class CheckpointManager:
    """Rolling checkpoints + preemption-safe save."""

    def __init__(self, directory: str, keep: int = 3, every: int = 100):
        self.directory = directory
        self.keep = keep
        self.every = every

    def maybe_save(self, step: int, state, extra=None, force: bool = False):
        if not force and (step == 0 or step % self.every):
            return None
        path = save_checkpoint(self.directory, step, state, extra)
        self._gc()
        return path

    def latest(self):
        return find_latest(self.directory)

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for stale in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, stale),
                          ignore_errors=True)
