"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python per grid step, which validates the exact TPU
program logic. On a TPU backend the same wrappers emit Mosaic kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import diameter as _diameter
from repro.kernels import pairwise_l2 as _pairwise
from repro.kernels import project_bin as _project


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_l2_join(a: jax.Array, b: jax.Array,
                     r: float | jax.Array = float("inf"), *,
                     bm: int = 128, bn: int = 128,
                     interpret: bool | None = None):
    """Blocked pairwise sq-L2 + threshold-join counts. Returns (sq, counts)
    where counts is the per-tile join-size grid (sum() = edge weight). ``r``
    is a traced operand (SMEM scalar): per-query r_k sweeps share one
    compiled program."""
    interpret = _default_interpret() if interpret is None else interpret
    return _pairwise.pairwise_l2_join(a, b, r, bm=bm, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_l2_join_batched(x: jax.Array, lengths: jax.Array,
                             r: jax.Array | float = float("inf"), *,
                             bm: int = 128, bn: int = 128,
                             interpret: bool | None = None):
    """One fused self-join over a batch of padded subsets (S, P, d) with
    per-subset valid lengths (S,) and per-subset radii (S,). Returns
    (sq (S, P, P), counts (S, gm, gn))."""
    interpret = _default_interpret() if interpret is None else interpret
    return _pairwise.pairwise_l2_join_batched(x, lengths, r, bm=bm, bn=bn,
                                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("w", "c", "bn", "interpret"))
def project_and_bin(x: jax.Array, z: jax.Array, w: float, c: int, *,
                    bn: int = 256, interpret: bool | None = None):
    """Fused projection + dual-bin keys (eqs. 1-2). Returns (h1, h2, proj)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _project.project_and_bin(x, z, w, c, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def tuple_diameters(pts: jax.Array, *, bt: int = 128,
                    interpret: bool | None = None):
    """Batched candidate diameters r(A) for padded tuples (T, q, d)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _diameter.tuple_diameters(pts, bt=bt, interpret=interpret)


def pairwise_distances(a, b, *, interpret: bool | None = None) -> jnp.ndarray:
    """Convenience: dense (M, N) Euclidean distances via the join kernel."""
    sq, _ = pairwise_l2_join(a, b, interpret=interpret)
    return jnp.sqrt(sq)
