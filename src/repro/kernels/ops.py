"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python per grid step, which validates the exact TPU
program logic. On a TPU backend the same wrappers emit Mosaic kernels.

The serving hot path (:func:`pairwise_l2_join_batched_masked`) additionally
routes by *implementation*: the Pallas program is a Mosaic artifact, and
interpreting it per grid step is a debugging tool, not a lowering — a
(S, gm, gn) grid costs milliseconds of Python per step. Off-TPU the same
math (the ``kernels.ref`` formulation, bit-exact in fp32 modulo reduction
order) compiles through XLA instead, so ``impl=None`` picks Mosaic on TPU
and the XLA lowering everywhere else. Kernel-validation tests pin
``impl="pallas", interpret=True`` to keep exercising the TPU program logic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import diameter as _diameter
from repro.kernels import pairwise_l2 as _pairwise
from repro.kernels import project_bin as _project


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_l2_join(a: jax.Array, b: jax.Array,
                     r: float | jax.Array = float("inf"), *,
                     bm: int = 128, bn: int = 128,
                     interpret: bool | None = None):
    """Blocked pairwise sq-L2 + threshold-join counts. Returns (sq, counts)
    where counts is the per-tile join-size grid (sum() = edge weight). ``r``
    is a traced operand (SMEM scalar): per-query r_k sweeps share one
    compiled program."""
    interpret = _default_interpret() if interpret is None else interpret
    return _pairwise.pairwise_l2_join(a, b, r, bm=bm, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_l2_join_batched(x: jax.Array, lengths: jax.Array,
                             r: jax.Array | float = float("inf"), *,
                             bm: int = 128, bn: int = 128,
                             interpret: bool | None = None):
    """One fused self-join over a batch of padded subsets (S, P, d) with
    per-subset valid lengths (S,) and per-subset radii (S,). Returns
    (sq (S, P, P), counts (S, gm, gn))."""
    interpret = _default_interpret() if interpret is None else interpret
    return _pairwise.pairwise_l2_join_batched(x, lengths, r, bm=bm, bn=bn,
                                              interpret=interpret)


def _xla_join_batched_masked(x, lengths, r, with_sq):
    """Optimized XLA lowering of the masked batched self-join.

    Same contract as the Pallas kernel, tuned for memory traffic: one batched
    gemm for the Gram term, one fused elementwise pass for the join bits, and
    a (…, 16)-wide fp32 matvec that packs 16-bit half-words exactly (max
    0xFFFF < 2^24) — no 32x uint32 broadcast like the naive pack. Counts come
    from popcounting the packed words (cells/32 traffic instead of cells).
    """
    n_subsets, p, _ = x.shape
    xf = x.astype(jnp.float32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape((n_subsets,))
    r2 = jnp.square(jnp.broadcast_to(jnp.asarray(r, jnp.float32), (n_subsets,)))
    n2 = jnp.sum(xf * xf, axis=-1)                              # (S, P)
    gram = jax.lax.dot_general(xf, xf, (((2,), (2,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)
    sq = jnp.maximum(n2[:, :, None] + n2[:, None, :] - 2.0 * gram, 0.0)
    valid_row = jnp.arange(p)[None, :] < lengths[:, None]       # (S, P)
    joined = ((sq <= r2[:, None, None])
              & valid_row[:, :, None] & valid_row[:, None, :])
    w = (p + 31) // 32
    bits = jnp.pad(joined.astype(jnp.float32),
                   ((0, 0), (0, 0), (0, w * 32 - p)))
    halves = bits.reshape(n_subsets, p, w, 2, 16) @ (
        jnp.uint32(1) << jnp.arange(16, dtype=jnp.uint32)).astype(jnp.float32)
    mask = (halves[..., 0].astype(jnp.uint32)
            | (halves[..., 1].astype(jnp.uint32) << 16))        # (S, P, W)
    cnt = jnp.sum(jax.lax.population_count(mask), axis=(1, 2)) \
        .astype(jnp.int32)
    if with_sq:
        fmax = jnp.float32(jnp.finfo(jnp.float32).max)
        sq = jnp.where(valid_row[:, :, None] & valid_row[:, None, :], sq, fmax)
        return mask, cnt, sq
    return mask, cnt


def _elig_dense(elig, p):
    """Packed (S, ceil(P/32)) uint32 eligibility words -> dense (S, P) bool."""
    col = jnp.arange(p)
    return ((elig[:, col // 32] >> (col % 32).astype(jnp.uint32))
            & jnp.uint32(1)) > 0


def _xla_join_batched_counts(x, lengths, r, elig_row, dtype):
    """XLA lowering of the coarse prune tier: per-subset join counts only.

    ``dtype`` picks the coarse arithmetic:

      * ``"bf16"`` — coordinates round to bfloat16, Gram matmul at bf16
        input precision with fp32 accumulation, self-norms computed from the
        same bf16 values in fp32. Identical math to the Pallas prune kernel
        (modulo reduction order, which the caller's slack radius covers).
      * ``"int8"`` — symmetric per-subset quantization
        ``q = round(x * 127 / maxabs)``; Gram and norms are *exact* int32,
        and the threshold is widened on the integer side by the worst-case
        quantization slack ``sqrt(d) * maxabs / 127`` (0.5 rounding error
        per coordinate, two endpoints), so the integer count is again a
        guaranteed upper bound of the fp32 join count.

    ``elig_row`` is a dense (S, P) bool eligibility mask (or None). Returns
    counts (S,) int32.
    """
    n_subsets, p, d = x.shape
    lengths = jnp.asarray(lengths, jnp.int32).reshape((n_subsets,))
    rr = jnp.broadcast_to(jnp.asarray(r, jnp.float32), (n_subsets,))
    valid_row = jnp.arange(p)[None, :] < lengths[:, None]        # (S, P)
    if elig_row is not None:
        valid_row = valid_row & elig_row
    if dtype == "int8":
        xf = x.astype(jnp.float32)
        maxabs = jnp.maximum(jnp.max(jnp.abs(xf), axis=(1, 2)),
                             jnp.float32(1e-30))                 # (S,)
        scale = jnp.float32(127.0) / maxabs
        q = jnp.round(xf * scale[:, None, None]).astype(jnp.int8)
        qi = q.astype(jnp.int32)
        n2 = jnp.sum(qi * qi, axis=-1)                           # (S, P) exact
        gram = jax.lax.dot_general(q, q, (((2,), (2,)), ((0,), (0,))),
                                   preferred_element_type=jnp.int32)
        sq = n2[:, :, None] + n2[:, None, :] - 2 * gram          # exact int32
        # ||x_i - x_j|| >= (||q_i - q_j|| - sqrt(d)) / scale: include iff
        # ||q||^2 <= (r*scale + sqrt(d))^2, +1 absorbs the fp32 threshold
        # rounding (the quadratic fits int32: d * 254^2).
        rq = rr * scale + jnp.float32(d) ** 0.5
        thr = (jnp.ceil(rq * rq) + 1.0).astype(jnp.int32)
        joined = sq <= thr[:, None, None]
    elif dtype == "bf16":
        xb = x.astype(jnp.bfloat16)
        xf = xb.astype(jnp.float32)
        r2 = jnp.square(rr)
        n2 = jnp.sum(xf * xf, axis=-1)                           # (S, P)
        gram = jax.lax.dot_general(xb, xb, (((2,), (2,)), ((0,), (0,))),
                                   preferred_element_type=jnp.float32)
        sq = jnp.maximum(n2[:, :, None] + n2[:, None, :] - 2.0 * gram, 0.0)
        joined = sq <= r2[:, None, None]
    else:
        raise ValueError(f"unknown prune dtype: {dtype!r}")
    joined = joined & valid_row[:, :, None] & valid_row[:, None, :]
    return jnp.sum(joined, axis=(1, 2), dtype=jnp.int32)


def join_batched_counts_local(x, lengths, r, elig=None, *, dtype: str = "bf16",
                              bm: int = 128, bn: int = 128,
                              impl: str | None = None,
                              interpret: bool | None = None):
    """Un-jit'd coarse prune-tier counts, safe to call under an outer trace
    (``core.device_plane`` shard_maps it). ``elig`` uses the packed uint32
    word layout shared with the masked join; the Pallas lowering consumes it
    as a dense fp32 row (unpacked at trace time). ``impl="pallas"`` requires
    ``dtype="bf16"`` — the int8 path is XLA-only (int8 Gram through Mosaic is
    a ROADMAP item). Returns counts (S,) int32."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl: {impl!r}")
    if impl == "pallas" and dtype != "bf16":
        impl = "xla"
    interpret = _default_interpret() if interpret is None else interpret
    p = x.shape[1]
    elig_row = None if elig is None \
        else _elig_dense(jnp.asarray(elig, jnp.uint32), p)
    if impl == "xla":
        return _xla_join_batched_counts(x, lengths, r, elig_row, dtype)
    ones = jnp.ones(x.shape[:2], jnp.float32) if elig_row is None \
        else elig_row.astype(jnp.float32)
    cnt = _pairwise.pairwise_l2_join_batched_prune(
        x, lengths, r, ones, bm=bm, bn=bn, interpret=interpret)
    return cnt.sum(axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("dtype", "bm", "bn", "impl",
                                             "interpret"))
def _join_batched_counts(x, lengths, r, elig, *, dtype, bm, bn, impl,
                         interpret):
    return join_batched_counts_local(x, lengths, r, elig, dtype=dtype, bm=bm,
                                     bn=bn, impl=impl, interpret=interpret)


def pairwise_l2_join_batched_counts(x: jax.Array, lengths: jax.Array,
                                    r: jax.Array | float,
                                    elig: jax.Array | None = None, *,
                                    dtype: str = "bf16", bm: int = 128,
                                    bn: int = 128, impl: str | None = None,
                                    interpret: bool | None = None):
    """Coarse mixed-precision threshold-join counts (the cascade's tier 0).

    Same batching contract as :func:`pairwise_l2_join_batched_masked` but
    counts-only: no mask is materialised, no dense block, the readback is S
    int32 words. Call with the error-widened coarse radii; a subset whose
    count is at or below its live diagonal provably has no off-diagonal fp32
    pair, so the fp32 masked join can skip it. ``dtype`` is "bf16" or
    "int8" (int8 is XLA-only)."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl: {impl!r}")
    interpret = _default_interpret() if interpret is None else interpret
    return _join_batched_counts(x, lengths, r, elig, dtype=dtype, bm=bm,
                                bn=bn, impl=impl, interpret=interpret)


def _fold_eligibility(mask, cnt, elig):
    """AND a packed per-subset eligibility vector into the packed join mask.

    ``elig`` is (S, ceil(P/32)) uint32 — bit ``j % 32`` of word ``j // 32``
    set iff point j of the subset satisfies the query's predicate (same
    LSB-first layout as the mask words). Folding is two elementwise passes on
    the packed words (columns: one AND against the broadcast eligibility
    row; rows: zero every ineligible row, the row bit gathered back out of
    the packed words), so the output *is* the existing (S, P, ceil(P/32))
    layout — eligibility adds H2D words but no new device->host transfer,
    and join counts become eligible-pair counts (popcount of the folded
    mask), which is what drives the empty-join host-enumeration skip at low
    selectivity."""
    s, p, _ = mask.shape
    col = jnp.arange(p)
    row_bit = (elig[:, col // 32] >> (col % 32).astype(jnp.uint32)) & jnp.uint32(1)
    folded = jnp.where((row_bit > 0)[:, :, None],
                       mask & elig[:, None, :], jnp.uint32(0))
    cnt = jnp.sum(jax.lax.population_count(folded), axis=(1, 2)) \
        .astype(jnp.int32)
    return folded, cnt


def join_batched_masked_local(x, lengths, r, elig=None, *, bm: int = 128,
                              bn: int = 128, with_sq: bool = False,
                              impl: str | None = None,
                              interpret: bool | None = None):
    """Un-jit'd masked batched self-join, safe to call under an outer trace.

    Same contract as :func:`pairwise_l2_join_batched_masked` but composable:
    ``core.device_plane`` calls this inside a ``shard_map`` body so each mesh
    shard runs the join on its local (S/n, P, d) slab. ``impl`` routing is
    resolved at trace time (Mosaic on TPU, the XLA lowering elsewhere).
    ``elig`` (packed (S, ceil(P/32)) uint32 eligibility words) ANDs a
    filtered query's point-eligibility into the mask and counts — a fused
    epilogue on the packed words, identical math on either lowering."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl: {impl!r}")
    interpret = _default_interpret() if interpret is None else interpret
    if impl == "xla":
        out = _xla_join_batched_masked(x, lengths, r, with_sq)
        if with_sq:
            mask, cnt, sq = out
        else:
            mask, cnt = out
    else:
        out = _pairwise.pairwise_l2_join_batched_masked(
            x, lengths, r, bm=bm, bn=bn, with_sq=with_sq, interpret=interpret)
        if with_sq:
            mask, cnt, sq = out
        else:
            mask, cnt = out
        cnt = cnt.sum(axis=(1, 2))
    if elig is not None:
        mask, cnt = _fold_eligibility(mask, cnt, jnp.asarray(elig, jnp.uint32))
    if with_sq:
        return mask, cnt, sq
    return mask, cnt


@functools.partial(jax.jit, static_argnames=("bm", "bn", "with_sq", "impl",
                                             "interpret"))
def _join_batched_masked(x, lengths, r, elig, *, bm, bn, with_sq, impl,
                         interpret):
    return join_batched_masked_local(x, lengths, r, elig, bm=bm, bn=bn,
                                     with_sq=with_sq, impl=impl,
                                     interpret=interpret)


def pairwise_l2_join_batched_masked(x: jax.Array, lengths: jax.Array,
                                    r: jax.Array | float = float("inf"),
                                    elig: jax.Array | None = None, *,
                                    bm: int = 128, bn: int = 128,
                                    with_sq: bool = False,
                                    impl: str | None = None,
                                    interpret: bool | None = None):
    """Fused batched self-join emitting the packed adjacency bitmask.

    Returns ``(mask, counts[, sq])`` — mask (S, P, ceil(P/32)) uint32 (bit
    ``j % 32`` of word ``j // 32`` of row i set iff points i, j of the subset
    join at its radius), counts (S,) int32 per-subset join cardinalities
    (diagonal included), and the dense fp32 block only when ``with_sq``.

    ``elig`` ((S, ceil(P/32)) uint32, same LSB-first packing as the mask)
    scopes the join to a filtered query's eligible points: ineligible rows
    and columns are zeroed in the output mask and counts become
    eligible-pair counts — fused into the same program, so the D2H readback
    is byte-identical to the unfiltered dispatch.

    ``impl`` selects the lowering: "pallas" (the Mosaic kernel; interpreted
    off-TPU), "xla" (the reference formulation compiled by XLA), or None to
    pick "pallas" on TPU and "xla" elsewhere. Both lowerings share the mask
    contract bit-for-bit on identical fp32 inputs.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl: {impl!r}")
    interpret = _default_interpret() if interpret is None else interpret
    return _join_batched_masked(x, lengths, r, elig, bm=bm, bn=bn,
                                with_sq=with_sq, impl=impl,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("w", "c", "bn", "interpret"))
def project_and_bin(x: jax.Array, z: jax.Array, w: float, c: int, *,
                    bn: int = 256, interpret: bool | None = None):
    """Fused projection + dual-bin keys (eqs. 1-2). Returns (h1, h2, proj)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _project.project_and_bin(x, z, w, c, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def tuple_diameters(pts: jax.Array, *, bt: int = 128,
                    interpret: bool | None = None):
    """Batched candidate diameters r(A) for padded tuples (T, q, d)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _diameter.tuple_diameters(pts, bt=bt, interpret=interpret)


def pairwise_distances(a, b, *, interpret: bool | None = None) -> jnp.ndarray:
    """Convenience: dense (M, N) Euclidean distances via the join kernel."""
    sq, _ = pairwise_l2_join(a, b, interpret=interpret)
    return jnp.sqrt(sq)
