"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_l2_join_ref(a: jax.Array, b: jax.Array, r: float = jnp.inf
                         ) -> tuple[jax.Array, jax.Array]:
    """(sq distances (M,N) fp32, total join count scalar int32)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    sq = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * (a @ b.T))
    sq = jnp.maximum(sq, 0.0)
    cnt = jnp.sum(sq <= float(r) ** 2, dtype=jnp.int32)
    return sq, cnt


def pairwise_l2_join_batched_ref(x: jax.Array, lengths, r
                                 ) -> tuple[jax.Array, jax.Array]:
    """Per-subset (sq (S,P,P) with fmax outside the valid square, counts (S,))
    oracle for the batched self-join kernel."""
    x = x.astype(jnp.float32)
    n_subsets, p, _ = x.shape
    lengths = jnp.asarray(lengths, jnp.int32).reshape((n_subsets,))
    r2 = jnp.square(jnp.broadcast_to(jnp.asarray(r, jnp.float32), (n_subsets,)))
    n2 = jnp.sum(x * x, axis=-1)                               # (S, P)
    gram = jnp.einsum("spd,sqd->spq", x, x)
    sq = jnp.maximum(n2[:, :, None] + n2[:, None, :] - 2.0 * gram, 0.0)
    idx = jnp.arange(p)
    valid = ((idx[None, :, None] < lengths[:, None, None])
             & (idx[None, None, :] < lengths[:, None, None]))
    sq = jnp.where(valid, sq, jnp.float32(jnp.finfo(jnp.float32).max))
    cnt = jnp.sum((sq <= r2[:, None, None]) & valid, axis=(1, 2),
                  dtype=jnp.int32)
    return sq, cnt


def pack_join_mask_ref(joined: jax.Array) -> jax.Array:
    """(S, P, N) bool -> (S, P, ceil(N/32)) uint32, LSB-first within a word."""
    s, p, n = joined.shape
    w = (n + 31) // 32
    bits = jnp.pad(joined.astype(jnp.uint32), ((0, 0), (0, 0), (0, w * 32 - n)))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits.reshape(s, p, w, 32) << shifts, axis=-1,
                   dtype=jnp.uint32)


def pairwise_l2_join_batched_masked_ref(x: jax.Array, lengths, r,
                                        with_sq: bool = False):
    """Oracle for the masked batched self-join: ``(mask, counts[, sq])`` with
    mask (S, P, ceil(P/32)) uint32, counts (S,) int32 — and the XLA lowering
    of the same math for off-TPU backends (see ``kernels.ops``)."""
    sq, cnt = pairwise_l2_join_batched_ref(x, lengths, r)
    n_subsets, p, _ = x.shape
    lengths = jnp.asarray(lengths, jnp.int32).reshape((n_subsets,))
    r2 = jnp.square(jnp.broadcast_to(jnp.asarray(r, jnp.float32), (n_subsets,)))
    idx = jnp.arange(p)
    valid = ((idx[None, :, None] < lengths[:, None, None])
             & (idx[None, None, :] < lengths[:, None, None]))
    mask = pack_join_mask_ref((sq <= r2[:, None, None]) & valid)
    if with_sq:
        return mask, cnt, sq
    return mask, cnt


def project_and_bin_ref(x: jax.Array, z: jax.Array, w: float, c: int
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(h1, h2, proj) per paper eqs. 1-2; z is (m, d)."""
    p = x.astype(jnp.float32) @ z.astype(jnp.float32).T
    h1 = jnp.floor(p / w).astype(jnp.int32)
    h2 = (jnp.floor((p - w / 2.0) / w) + c).astype(jnp.int32)
    return h1, h2, p


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Dense-softmax oracle for the flash kernel (per-q-head layout)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    scale = 1.0 / float(hd) ** 0.5
    sc = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(s)[:, None]
    kv_pos = jnp.arange(t)[None, :]
    valid = jnp.ones((s, t), bool)
    if causal:
        valid = valid & (kv_pos <= q_pos)
    if window is not None:
        valid = valid & (kv_pos > q_pos - window)
    sc = jnp.where(valid[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def tuple_diameters_ref(pts: jax.Array) -> jax.Array:
    """(T, q, d) -> (T,) max pairwise distances."""
    pts = pts.astype(jnp.float32)
    sq = jnp.sum(pts * pts, axis=-1)
    gram = jnp.einsum("tqd,trd->tqr", pts, pts)
    d2 = jnp.maximum(sq[:, :, None] + sq[:, None, :] - 2.0 * gram, 0.0)
    return jnp.sqrt(jnp.max(d2, axis=(1, 2)))
