"""Pallas TPU kernels: blocked pairwise squared-L2 **threshold join**.

This is the paper's hot spot (§V pairwise inner joins + Algorithm 4's distance
predicate). One fused pass computes, for tiles A:(bm,d), B:(bn,d) resident in
VMEM:

    sq[i,j]  = ||a_i||^2 + ||b_j||^2 - 2 a_i.b_j        (MXU matmul)
    count    = #{(i,j) : sq[i,j] <= r^2}                (the inner-join edge
                                                         weight M[vi,vj])

Two entry points share the kernel body:

  * :func:`pairwise_l2_join` — one (M, d) x (N, d) join. The threshold ``r``
    is a *runtime* scalar delivered through a scalar-prefetch SMEM ref, so
    per-query ``r_k`` thresholds never force a recompilation (they used to be
    baked into the kernel as a static float).
  * :func:`pairwise_l2_join_batched` — the serving hot path: a whole batch of
    padded subsets (S, P, d) self-joined in **one** dispatch, with per-subset
    lengths and per-subset radii prefetched into SMEM. This is what
    ``core.backend.PallasBackend`` calls once per scale for all covering-bucket
    subsets of a query batch.

A third entry point, :func:`pairwise_l2_join_batched_masked`, emits the join
*result* as a packed per-subset adjacency bitmask instead of (or in addition
to) the dense fp32 block: word ``mask[s, i, w]`` holds bits for columns
``32*w .. 32*w+31`` of row ``i`` (LSB-first), bit set iff
``sq[s, i, j] <= r[s]^2`` and both endpoints are valid. The mask is the
enumeration stage's entire join contract, so the D2H readback shrinks 32x
(uint32 words vs fp32 cells) and the dense ``sq`` block becomes optional.
In-kernel packing rides the MXU: the 0/1 bit tile is multiplied by a static
(bn, 2W) weight matrix of powers of two that accumulates each 16-bit half-word
exactly in fp32 (max 0xFFFF < 2^24), and the halves are fused into uint32
words with one shift-or.

Grid is (ceil(M/bm), ceil(N/bn)) (with a leading subset axis for the batched
variant); the full d extent is kept per block (for the embedding widths we
index, bm*d*4B + bn*d*4B + bm*bn*4B stays well inside the ~16 MiB v5e VMEM
budget: 128x8192 fp32 tiles are 4 MiB each). Tail tiles are masked with an
in-kernel iota validity test — no host-side padding games.

MXU notes: bm=bn=128 aligns the matmul to the 128x128 systolic array;
``preferred_element_type=float32`` keeps the accumulator fp32 even for bf16
inputs. The masked variant is interpret-validated; its (bm, bn//32) output
tile is narrower than one lane register, which Mosaic pads — real-TPU lane
utilisation of the mask store is part of the ROADMAP v5e validation item.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_FMAX = float(jnp.finfo(jnp.float32).max)


def _join_block(a, b):
    """sq-L2 block from fp32 tiles: ||a||^2 + ||b||^2 - 2ab on the MXU."""
    a2 = jnp.sum(a * a, axis=1, keepdims=True)    # (bm, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)    # (bn, 1)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bm, bn)
    return jnp.maximum(a2 + b2.T - 2.0 * ab, 0.0)


def _kernel(r2_ref, a_ref, b_ref, sq_ref, cnt_ref, *, m_actual: int,
            n_actual: int, bm: int, bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    sq = _join_block(a_ref[...].astype(jnp.float32),
                     b_ref[...].astype(jnp.float32))
    rows = (i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)) < m_actual
    cols = (j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)) < n_actual
    valid = rows & cols
    sq = jnp.where(valid, sq, jnp.float32(_FMAX))
    sq_ref[...] = sq
    cnt_ref[0, 0] = jnp.sum((sq <= r2_ref[0]) & valid, dtype=jnp.int32)


def pairwise_l2_join(a: jax.Array, b: jax.Array,
                     r: float | jax.Array = jnp.inf, *, bm: int = 128,
                     bn: int = 128, interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """Returns (sq, counts): sq (M,N) squared distances (invalid tail = fmax),
    counts (gm, gn) int32 per-tile join sizes. ``sum(counts)`` is the paper's
    inner-join edge weight for the group pair. ``r`` may be a traced scalar —
    it rides in SMEM, so sweeping r_k costs zero recompiles."""
    m, d = a.shape
    n, _ = b.shape
    gm = pl.cdiv(m, bm)
    gn = pl.cdiv(n, bn)
    a_p = jnp.pad(a, ((0, gm * bm - m), (0, 0)))
    b_p = jnp.pad(b, ((0, gn * bn - n), (0, 0)))
    r2 = jnp.square(jnp.asarray(r, jnp.float32)).reshape((1,))

    kern = functools.partial(_kernel, m_actual=m, n_actual=n, bm=bm, bn=bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j, r2_ref: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j, r2_ref: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, r2_ref: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, r2_ref: (i, j)),
        ],
    )
    sq, cnt = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.int32),
        ],
        interpret=interpret,
    )(r2, a_p, b_p)
    return sq[:m, :n], cnt


def _batched_kernel(len_ref, r2_ref, a_ref, b_ref, sq_ref, cnt_ref, *,
                    bm: int, bn: int):
    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    sq = _join_block(a_ref[0].astype(jnp.float32),
                     b_ref[0].astype(jnp.float32))
    n_valid = len_ref[s]
    rows = (i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)) < n_valid
    cols = (j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)) < n_valid
    valid = rows & cols
    sq = jnp.where(valid, sq, jnp.float32(_FMAX))
    sq_ref[0] = sq
    cnt_ref[0, 0, 0] = jnp.sum((sq <= r2_ref[s]) & valid, dtype=jnp.int32)


def pairwise_l2_join_batched(x: jax.Array, lengths: jax.Array,
                             r: jax.Array | float = jnp.inf, *, bm: int = 128,
                             bn: int = 128, interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """Self-join every padded subset of a batch in one fused dispatch.

    x        : (S, P, d) — S subsets, each padded to P points.
    lengths  : (S,) int32 — valid point count per subset; rows/cols past the
               length are masked (sq = fmax, excluded from counts).
    r        : per-subset join radii, (S,) or scalar, runtime-traced (SMEM).

    Returns (sq, counts): sq (S, P, P) squared distances, counts (S, gm, gn)
    per-tile join sizes (``counts.sum(axis=(1, 2))`` is the per-subset inner
    join cardinality).
    """
    n_subsets, p, d = x.shape
    gm = pl.cdiv(p, bm)
    gn = pl.cdiv(p, bn)
    p_pad = max(gm * bm, gn * bn)
    x_p = jnp.pad(x, ((0, 0), (0, p_pad - p), (0, 0)))
    lengths = jnp.asarray(lengths, jnp.int32).reshape((n_subsets,))
    r2 = jnp.square(jnp.broadcast_to(jnp.asarray(r, jnp.float32), (n_subsets,)))

    kern = functools.partial(_batched_kernel, bm=bm, bn=bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_subsets, gm, gn),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda s, i, j, *_: (s, i, 0)),
            pl.BlockSpec((1, bn, d), lambda s, i, j, *_: (s, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda s, i, j, *_: (s, i, j)),
            pl.BlockSpec((1, 1, 1), lambda s, i, j, *_: (s, i, j)),
        ],
    )
    sq, cnt = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_subsets, gm * bm, gn * bn), jnp.float32),
            jax.ShapeDtypeStruct((n_subsets, gm, gn), jnp.int32),
        ],
        interpret=interpret,
    )(lengths, r2, x_p, x_p)
    return sq[:, :p, :p], cnt


def _prune_block(a, b):
    """sq-L2 block from bf16 tiles: norms in fp32, Gram on the bf16 MXU.

    The matmul runs at bf16 input precision (the point of the prune tier —
    half the MXU input bandwidth), accumulated in fp32; the self-norm terms
    are computed from the *same* bf16 values upcast to fp32, so the only
    error sources are the bf16 rounding of the coordinates (bounded by the
    caller's slack radius) and the fp32 accumulation (covered by the fp32
    slack term)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    a2 = jnp.sum(af * af, axis=1, keepdims=True)   # (bm, 1)
    b2 = jnp.sum(bf * bf, axis=1, keepdims=True)   # (bn, 1)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bm, bn)
    return jnp.maximum(a2 + b2.T - 2.0 * ab, 0.0)


def _batched_prune_kernel(len_ref, r2_ref, a_ref, b_ref, ea_ref, eb_ref,
                          cnt_ref, *, bm: int, bn: int):
    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    sq = _prune_block(a_ref[0], b_ref[0])
    n_valid = len_ref[s]
    rows = (i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)) < n_valid
    cols = (j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)) < n_valid
    valid = rows & cols & (ea_ref[0][:, None] > 0.0) & (eb_ref[0][None, :] > 0.0)
    cnt_ref[0, 0, 0] = jnp.sum((sq <= r2_ref[s]) & valid, dtype=jnp.int32)


def pairwise_l2_join_batched_prune(x: jax.Array, lengths: jax.Array,
                                   r: jax.Array | float, elig: jax.Array, *,
                                   bm: int = 128, bn: int = 128,
                                   interpret: bool = False) -> jax.Array:
    """Coarse bf16 threshold-join: per-subset join *counts* only, no mask.

    The cascade's pruning tier. ``x`` is (S, P, d) **bfloat16** (cast outside
    the call so the H2D transfer itself is halved); ``r`` carries the
    error-widened coarse radii (``PallasBackend`` computes
    ``(r + slack32 + slack16) * (1 + eps)``), so the coarse count is a
    guaranteed upper bound of the fp32 join count. A subset whose coarse
    count stays at or below its live diagonal cannot produce an off-diagonal
    fp32 pair — the fp32 tier (and its 32x-heavier mask readback) is skipped
    for it entirely.

    ``elig`` is a dense (S, P) fp32 0/1 eligibility row (all-ones when no
    filter is active): ineligible points drop out of the counts so the
    diagonal bound matches the fp32 tier's eligible-pair counts.

    Returns counts (S, gm, gn) int32 (``sum(axis=(1, 2))`` per subset).
    """
    n_subsets, p, d = x.shape
    gm = pl.cdiv(p, bm)
    gn = pl.cdiv(p, bn)
    p_pad = max(gm * bm, gn * bn)
    x_p = jnp.pad(x.astype(jnp.bfloat16), ((0, 0), (0, p_pad - p), (0, 0)))
    e_p = jnp.pad(jnp.asarray(elig, jnp.float32), ((0, 0), (0, p_pad - p)))
    lengths = jnp.asarray(lengths, jnp.int32).reshape((n_subsets,))
    r2 = jnp.square(jnp.broadcast_to(jnp.asarray(r, jnp.float32), (n_subsets,)))

    kern = functools.partial(_batched_prune_kernel, bm=bm, bn=bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_subsets, gm, gn),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda s, i, j, *_: (s, i, 0)),
            pl.BlockSpec((1, bn, d), lambda s, i, j, *_: (s, j, 0)),
            pl.BlockSpec((1, bm), lambda s, i, j, *_: (s, i)),
            pl.BlockSpec((1, bn), lambda s, i, j, *_: (s, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1), lambda s, i, j, *_: (s, i, j)),
        ],
    )
    (cnt,) = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_subsets, gm, gn), jnp.int32)],
        interpret=interpret,
    )(lengths, r2, x_p, x_p, e_p, e_p)
    return cnt


def _pack_bits_mxu(bits: jax.Array, bn: int) -> jax.Array:
    """(bm, bn) 0/1 fp32 -> (bm, bn//32) uint32 words, LSB-first per word.

    One MXU matmul against a static (bn, 2W) powers-of-two weight accumulates
    the low/high 16-bit halves of every word exactly in fp32 (<= 0xFFFF), then
    a shift-or fuses them. Avoids >=3D reshapes inside the kernel, which keeps
    the Mosaic lowering to plain 2D vector/matrix ops.
    """
    w = bn // 32
    cc = jax.lax.broadcasted_iota(jnp.int32, (bn, 2 * w), 0)     # column id
    hh = jax.lax.broadcasted_iota(jnp.int32, (bn, 2 * w), 1)     # half slot
    target = cc // 32 + w * ((cc // 16) % 2)   # lo halves 0..W-1, hi W..2W-1
    # powers of two via integer shift: jnp.exp2 is a polynomial approximation
    # in fp32 (2^13 -> 8192.0039) and would corrupt the packed words
    pow2 = (jnp.uint32(1) << (cc % 16).astype(jnp.uint32)).astype(jnp.float32)
    weight = jnp.where(hh == target, pow2, 0.0)
    halves = jax.lax.dot_general(bits, weight, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    return (halves[:, :w].astype(jnp.uint32)
            | (halves[:, w:].astype(jnp.uint32) << 16))


def _batched_masked_kernel(len_ref, r2_ref, a_ref, b_ref, *out_refs,
                           bm: int, bn: int, with_sq: bool):
    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    sq = _join_block(a_ref[0].astype(jnp.float32),
                     b_ref[0].astype(jnp.float32))
    n_valid = len_ref[s]
    rows = (i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)) < n_valid
    cols = (j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)) < n_valid
    valid = rows & cols
    sq = jnp.where(valid, sq, jnp.float32(_FMAX))
    joined = (sq <= r2_ref[s]) & valid
    if with_sq:
        sq_ref, mask_ref, cnt_ref = out_refs
        sq_ref[0] = sq
    else:
        mask_ref, cnt_ref = out_refs
    mask_ref[0] = _pack_bits_mxu(joined.astype(jnp.float32), bn)
    cnt_ref[0, 0, 0] = jnp.sum(joined, dtype=jnp.int32)


def pairwise_l2_join_batched_masked(x: jax.Array, lengths: jax.Array,
                                    r: jax.Array | float = jnp.inf, *,
                                    bm: int = 128, bn: int = 128,
                                    with_sq: bool = False,
                                    interpret: bool = False):
    """Batched self-join emitting the packed adjacency bitmask.

    Same contract as :func:`pairwise_l2_join_batched` plus a packed join mask:

    Returns ``(mask, counts[, sq])``:
      mask   : (S, P, ceil(P/32)) uint32 — bit ``j % 32`` of ``mask[s, i, j//32]``
               is 1 iff ``sq[s, i, j] <= r[s]^2`` and i, j < lengths[s].
      counts : (S, gm, gn) int32 per-tile join sizes (``sum(axis=(1, 2))`` is
               the per-subset inner-join cardinality at r).
      sq     : dense (S, P, P) fp32 block, only when ``with_sq`` — the mask
               replaces it as the enumeration contract, making the 32x-larger
               dense readback optional.
    """
    if bn % 32:
        raise ValueError(f"bn must be a multiple of 32 for mask packing: {bn}")
    n_subsets, p, d = x.shape
    gm = pl.cdiv(p, bm)
    gn = pl.cdiv(p, bn)
    p_pad = max(gm * bm, gn * bn)
    x_p = jnp.pad(x, ((0, 0), (0, p_pad - p), (0, 0)))
    lengths = jnp.asarray(lengths, jnp.int32).reshape((n_subsets,))
    r2 = jnp.square(jnp.broadcast_to(jnp.asarray(r, jnp.float32), (n_subsets,)))
    wn = bn // 32

    kern = functools.partial(_batched_masked_kernel, bm=bm, bn=bn,
                             with_sq=with_sq)
    out_specs = [
        pl.BlockSpec((1, bm, wn), lambda s, i, j, *_: (s, i, j)),
        pl.BlockSpec((1, 1, 1), lambda s, i, j, *_: (s, i, j)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((n_subsets, gm * bm, gn * wn), jnp.uint32),
        jax.ShapeDtypeStruct((n_subsets, gm, gn), jnp.int32),
    ]
    if with_sq:
        out_specs.insert(0, pl.BlockSpec((1, bm, bn),
                                         lambda s, i, j, *_: (s, i, j)))
        out_shape.insert(0, jax.ShapeDtypeStruct(
            (n_subsets, gm * bm, gn * bn), jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_subsets, gm, gn),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda s, i, j, *_: (s, i, 0)),
            pl.BlockSpec((1, bn, d), lambda s, i, j, *_: (s, j, 0)),
        ],
        out_specs=out_specs,
    )
    out = pl.pallas_call(kern, grid_spec=grid_spec, out_shape=out_shape,
                         interpret=interpret)(lengths, r2, x_p, x_p)
    n_words = (p + 31) // 32
    if with_sq:
        sq, mask, cnt = out
        return mask[:, :p, :n_words], cnt, sq[:, :p, :p]
    mask, cnt = out
    return mask[:, :p, :n_words], cnt
