"""Pallas TPU kernel: blocked pairwise squared-L2 **threshold join**.

This is the paper's hot spot (§V pairwise inner joins + Algorithm 4's distance
predicate). One fused pass computes, for tiles A:(bm,d), B:(bn,d) resident in
VMEM:

    sq[i,j]  = ||a_i||^2 + ||b_j||^2 - 2 a_i.b_j        (MXU matmul)
    count    = #{(i,j) : sq[i,j] <= r^2}                (the inner-join edge
                                                         weight M[vi,vj])

Grid is (ceil(M/bm), ceil(N/bn)); the full d extent is kept per block (for the
embedding widths we index, bm*d*4B + bn*d*4B + bm*bn*4B stays well inside the
~16 MiB v5e VMEM budget: 128x8192 fp32 tiles are 4 MiB each). Tail tiles are
masked with an in-kernel iota validity test — no host-side padding games.

MXU notes: bm=bn=128 aligns the matmul to the 128x128 systolic array;
``preferred_element_type=float32`` keeps the accumulator fp32 even for bf16
inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, sq_ref, cnt_ref, *, m_actual: int, n_actual: int,
            bm: int, bn: int, r2: float):
    i = pl.program_id(0)
    j = pl.program_id(1)
    a = a_ref[...].astype(jnp.float32)            # (bm, d)
    b = b_ref[...].astype(jnp.float32)            # (bn, d)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)    # (bm, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)    # (bn, 1)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bm, bn)
    sq = jnp.maximum(a2 + b2.T - 2.0 * ab, 0.0)

    rows = (i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)) < m_actual
    cols = (j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)) < n_actual
    valid = rows & cols
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    sq = jnp.where(valid, sq, big)
    sq_ref[...] = sq
    cnt_ref[0, 0] = jnp.sum((sq <= r2) & valid, dtype=jnp.int32)


def pairwise_l2_join(a: jax.Array, b: jax.Array, r: float | jax.Array = jnp.inf,
                     *, bm: int = 128, bn: int = 128,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (sq, counts): sq (M,N) squared distances (invalid tail = fmax),
    counts (gm, gn) int32 per-tile join sizes. ``sum(counts)`` is the paper's
    inner-join edge weight for the group pair."""
    m, d = a.shape
    n, _ = b.shape
    gm = pl.cdiv(m, bm)
    gn = pl.cdiv(n, bn)
    pad_m = gm * bm - m
    pad_n = gn * bn - n
    a_p = jnp.pad(a, ((0, pad_m), (0, 0)))
    b_p = jnp.pad(b, ((0, pad_n), (0, 0)))
    r2 = float(r) ** 2 if not isinstance(r, jax.Array) else None
    if r2 is None:
        raise TypeError("r must be a static float for the fused-count kernel")

    kern = functools.partial(_kernel, m_actual=m, n_actual=n, bm=bm, bn=bn, r2=r2)
    sq, cnt = pl.pallas_call(
        kern,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.int32),
        ],
        interpret=interpret,
    )(a_p, b_p)
    return sq[:m, :n], cnt
