"""Pallas TPU kernel: fused flash attention (forward).

§Perf iteration 7: the HLO census shows the XLA-level online-softmax scan
writes scores/probabilities to HBM every KV block — 8.4 TB/device of the
qwen3 prefill_32k memory term (78%). Fusing the whole inner loop into one
Pallas kernel keeps sc/p_att in VMEM; HBM traffic drops to Q/K/V/O reads and
writes (the flash-attention contract).

Layout: grid (B*H, S/bq); each program owns a (bq, hd) query tile and loops
over KV blocks of size bk with fp32 running max/denominator/accumulator held
in VMEM scratch. Causality is handled by masking per block (programs whose
whole KV block is in the future still execute — Pallas grids are dense — but
contribute nothing; the MXU work is bounded by bq*bk*hd per step).

Weak-scaling notes vs the jnp path it replaces:
  * dots in input dtype (bf16) with fp32 accumulation;
  * GQA: callers expand K/V to per-q-head layout (models.common does this
    for the TP case already); the kernel is MHA-shaped (B, S, H, hd);
  * the jnp scan in models.common remains the CPU/interpret fallback and
    the oracle for this kernel's tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, t: int,
            causal: bool, window: int | None, scale: float):
    # q_ref: (bq, hd); k_ref/v_ref: (T, hd); o_ref: (bq, hd)
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # promoted once
    hd = q.shape[-1]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    nblk = -(-t // bk)          # ceil: padded KV is masked via kv_pos < t

    def body(i, carry):
        m_run, l_run, acc = carry
        k_c = pl.load(k_ref, (pl.dslice(i * bk, bk), slice(None)))
        v_c = pl.load(v_ref, (pl.dslice(i * bk, bk), slice(None)))
        sc = jax.lax.dot_general(
            q.astype(k_c.dtype), k_c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bk)
        kv_pos = i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kv_pos < t
        if causal:
            valid = valid & (kv_pos <= q_pos)
        if window is not None:
            valid = valid & (kv_pos > q_pos - window)
        sc = jnp.where(valid, sc, jnp.float32(-1e30))
        m_new = jnp.maximum(m_run, sc.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(sc - m_new[:, None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p.astype(v_c.dtype), v_c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    # causal: skip blocks strictly after this query tile
    hi = nblk if not causal else jnp.minimum(
        nblk, (qi + 1) * bq // bk + 1).astype(jnp.int32)
    m_f, l_f, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l_f, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B,S,H,hd); k,v (B,T,H,hd) [per-q-head layout] -> (B,S,H,hd).

    T and S are padded to the block sizes internally; padded KV is masked.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    bq = min(bq, max(8, s))
    bk = min(bk, max(128, t))
    gs = pl.cdiv(s, bq)
    tpad = pl.cdiv(t, bk) * bk - t
    spad = gs * bq - s
    # flatten (B,H) into the grid's first axis; seq-major layout per head
    qf = jnp.pad(q, ((0, 0), (0, spad), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3).reshape(b * h, gs * bq, hd)
    kf = jnp.pad(k, ((0, 0), (0, tpad), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3).reshape(b * h, t + tpad, hd)
    vf = jnp.pad(v, ((0, 0), (0, tpad), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3).reshape(b * h, t + tpad, hd)

    kern = functools.partial(_kernel, bq=bq, bk=bk, t=t, causal=causal,
                             window=window, scale=1.0 / float(hd) ** 0.5)
    out = pl.pallas_call(
        kern,
        grid=(b * h, gs),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((None, t + tpad, hd), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((None, t + tpad, hd), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, gs * bq, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, gs * bq, hd)[:, :, :s].transpose(0, 2, 1, 3)
