"""Pallas TPU kernel: fused random projection + dual-bin hashing (paper eqs 1-2).

Index build hot spot: every point is projected onto m unit vectors (an (N,d)
x (d,m) MXU matmul) and immediately binned:

    h1 = floor(p / w)
    h2 = floor((p - w/2) / w) + C

Fusing the floor-bins into the matmul kernel avoids materialising the (N, m)
projection matrix in HBM during index build — the bins are the only thing the
hashtable assembly needs (projections round-trip HBM only when the caller
asks for them, e.g. to compute pMax once).

Grid tiles N by ``bn`` rows; m is zero-padded to the lane width inside the
wrapper so the (d, m) operand keeps a TPU-friendly trailing dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128


def _kernel(x_ref, z_ref, h1_ref, h2_ref, p_ref, *, w: float, c: int):
    x = x_ref[...].astype(jnp.float32)            # (bn, d)
    z = z_ref[...].astype(jnp.float32)            # (d, mp)
    p = jnp.dot(x, z, preferred_element_type=jnp.float32)   # (bn, mp) on MXU
    inv_w = jnp.float32(1.0 / w)
    h1_ref[...] = jnp.floor(p * inv_w).astype(jnp.int32)
    h2_ref[...] = (jnp.floor((p - jnp.float32(w / 2.0)) * inv_w)
                   + jnp.int32(c)).astype(jnp.int32)
    p_ref[...] = p


def project_and_bin(x: jax.Array, z: jax.Array, w: float, c: int,
                    *, bn: int = 256, interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (N, d) points; z: (m, d) unit vectors. Returns (h1, h2, proj), each
    (N, m); h2 already offset by C (paper's disambiguation constant)."""
    n, d = x.shape
    m = z.shape[0]
    mp = max(_LANE, m)                             # pad lanes
    z_t = jnp.zeros((d, mp), dtype=z.dtype).at[:, :m].set(z.T)
    gn = pl.cdiv(n, bn)
    x_p = jnp.pad(x, ((0, gn * bn - n), (0, 0)))

    kern = functools.partial(_kernel, w=float(w), c=int(c))
    h1, h2, p = pl.pallas_call(
        kern,
        grid=(gn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, mp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, mp), lambda i: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gn * bn, mp), jnp.int32),
            jax.ShapeDtypeStruct((gn * bn, mp), jnp.int32),
            jax.ShapeDtypeStruct((gn * bn, mp), jnp.float32),
        ],
        interpret=interpret,
    )(x_p, z_t)
    return h1[:n, :m], h2[:n, :m], p[:n, :m]
