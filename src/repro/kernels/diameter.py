"""Pallas TPU kernel: batched candidate-diameter computation.

Ranks candidates by the paper's r(A) = max pairwise L2 distance. Input is a
padded batch of candidate tuples (T, q, d) — q <= 9 per the paper's query
sizes; callers pad short tuples by repeating a member point (a zero-distance
duplicate never changes the max).

Per grid step a (bt, q, d) block is reduced entirely in VMEM: q^2 dots via a
single (bt*q, d) x (d, bt*q)-free einsum — implemented as dot_general with a
batch dim so each tuple's Gram matrix stays (q, q).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pts_ref, out_ref):
    pts = pts_ref[...].astype(jnp.float32)         # (bt, q, d)
    sq = jnp.sum(pts * pts, axis=-1)               # (bt, q)
    gram = jax.lax.dot_general(
        pts, pts, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # (bt, q, q)
    d2 = sq[:, :, None] + sq[:, None, :] - 2.0 * gram
    d2 = jnp.maximum(d2, 0.0)
    out_ref[...] = jnp.sqrt(jnp.max(d2, axis=(1, 2)))[:, None]


def tuple_diameters(pts: jax.Array, *, bt: int = 128,
                    interpret: bool = False) -> jax.Array:
    """pts: (T, q, d) padded candidate tuples -> (T,) diameters r(A)."""
    t, q, d = pts.shape
    gt = pl.cdiv(t, bt)
    pts_p = jnp.pad(pts, ((0, gt * bt - t), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        grid=(gt,),
        in_specs=[pl.BlockSpec((bt, q, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gt * bt, 1), jnp.float32),
        interpret=interpret,
    )(pts_p)
    return out[:t, 0]
